"""dyflow's whole-program layer: an interprocedural call graph over
``src/repro``.

The per-module dyslint passes (DY1xx–DY4xx) see one file at a time;
the contracts they enforce — units flowing through the economics
formulas, the reachability of the bit-identity pins — cross module
boundaries through three kinds of dispatch this module resolves
statically:

  * **direct calls** — ``f(...)`` on module-level functions, imported
    functions, and nested defs;
  * **method dispatch** — ``obj.m(...)`` where the receiver's class is
    annotated (parameter/AnnAssign annotations), constructed in scope
    (``x = ClassName(...)``), or an attribute whose type the class's
    ``__init__``/body declares; a call through a base class fans out to
    every in-program override (may-call over-approximation);
  * **registry dispatch** — the ``RedistributionPolicy`` registry
    (``contracts.POLICY_REGISTRY``): a value produced by
    ``resolve_policy``/``make_policy``/``policy_class`` is "some
    registered policy", so calls on it edge to that method on the base
    class and on every ``@register_policy`` class.

Anything still unresolvable — a callable plucked from a container, a
``Callable`` field, ``getattr`` — degrades to an edge to the
:data:`UNKNOWN` sentinel, never to a silent drop: the pin-impact pass
records it so a closure that contains ``<unknown>`` is visibly
over-approximate rather than quietly incomplete.  References to program
functions in non-call position (``partial(f, ...)``, callbacks, heap
payloads, decorators) also create edges, which is what carries the
closure through the engine's jit/partial plumbing.

Like the rest of ``tools/lint`` this runs on a bare Python: no
``repro`` import, no numpy/jax.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from tools.lint import Module
from tools.lint.astutil import ImportMap, dotted

#: The sound "I could not resolve this callee" sink node.
UNKNOWN = "<unknown>"

#: Node id of a module's top-level code (imports, class bodies,
#: decorator applications, dataclass field factories).
MODULE_NODE = "<module>"


def node_id(path: str, qualname: str) -> str:
    return f"{path}::{qualname}"


@dataclasses.dataclass
class FunctionInfo:
    """One function or method definition."""

    node_id: str
    path: str
    qualname: str            # "f", "Cls.m", "outer.inner"
    name: str
    node: ast.AST            # FunctionDef / AsyncFunctionDef
    cls: Optional[str] = None      # owning class name, if a method
    params: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ClassInfo:
    """One class definition with its in-program inheritance links."""

    path: str
    name: str
    node: ast.ClassDef
    methods: Dict[str, FunctionInfo] = dataclasses.field(
        default_factory=dict
    )
    base_exprs: List[ast.expr] = dataclasses.field(default_factory=list)
    bases: List["ClassInfo"] = dataclasses.field(default_factory=list)
    is_registered_policy: bool = False
    #: Attribute name -> ("class", ClassInfo) | ("policy",) — inferred
    #: from __init__ assignments and class-body annotations.
    attr_types: Dict[str, Tuple] = dataclasses.field(default_factory=dict)

    def mro(self) -> List["ClassInfo"]:
        """Linearized in-program ancestry (self first, duplicates
        dropped); external bases simply end a branch."""
        out: List[ClassInfo] = []
        stack: List[ClassInfo] = [self]
        while stack:
            c = stack.pop(0)
            if c not in out:
                out.append(c)
                stack.extend(c.bases)
        return out

    def find_method(self, name: str) -> Optional[FunctionInfo]:
        for c in self.mro():
            if name in c.methods:
                return c.methods[name]
        return None


@dataclasses.dataclass
class ModuleInfo:
    """A parsed module plus its symbol tables."""

    path: str
    module: Module
    imports: ImportMap
    mod_name: str            # "repro.core.policy"
    functions: Dict[str, FunctionInfo] = dataclasses.field(
        default_factory=dict
    )
    classes: Dict[str, ClassInfo] = dataclasses.field(default_factory=dict)


class ModuleCache:
    """Parse each source file exactly once; shared by the per-module
    passes, the call graph, and the units pass (the `--jobs` runner
    hands one cache per worker)."""

    def __init__(self, root: str):
        self.root = root
        self._mods: Dict[str, Module] = {}

    def get(self, relpath: str) -> Module:
        mod = self._mods.get(relpath)
        if mod is None:
            full = os.path.join(self.root, relpath)
            with open(full, encoding="utf-8") as fh:
                text = fh.read()
            mod = Module.from_source(relpath, text)
            self._mods[relpath] = mod
        return mod


def _mod_name(relpath: str) -> str:
    """src/repro/core/policy.py -> repro.core.policy"""
    p = relpath[:-3] if relpath.endswith(".py") else relpath
    parts = p.split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


# Inferred-type lattice values (plain tuples, matched by first element):
#   ("class", ClassInfo)   — an instance of a known program class
#   ("classref", ClassInfo) — the class OBJECT itself (constructor)
#   ("policy",)            — some registered policy instance
#   ("policyref",)         — some registered policy class object
#   ("seq", T)             — a list/tuple/comprehension of T
#   ("funcref", fi)        — a program function object (nested defs,
#                            factory results); calling it applies its
#                            return annotation
_POLICY = ("policy",)
_POLICY_REF = ("policyref",)


class Program:
    """The whole-program index + call graph.  Build once per lint run
    via :meth:`build`; reuse the :class:`ModuleCache` it was built from
    for the per-module passes."""

    def __init__(self, root: str, contracts, cache: ModuleCache):
        self.root = root
        self.contracts = contracts
        self.cache = cache
        self.modules: Dict[str, ModuleInfo] = {}
        self.functions: Dict[str, FunctionInfo] = {}
        self.classes: List[ClassInfo] = []
        self.edges: Dict[str, Set[str]] = {}
        self.broken: Dict[str, str] = {}     # relpath -> syntax error
        self._by_mod_func: Dict[Tuple[str, str], FunctionInfo] = {}
        self._by_mod_class: Dict[Tuple[str, str], ClassInfo] = {}
        self._by_astnode: Dict[int, FunctionInfo] = {}
        self._subclasses: Dict[int, List[ClassInfo]] = {}
        self._policy_classes: List[ClassInfo] = []
        self._policy_base: Optional[ClassInfo] = None
        self._prog_roots: Optional[Set[str]] = None
        self._attrs_in_progress: Set[int] = set()

    # ------------------------------------------------------------- #
    # Construction
    # ------------------------------------------------------------- #

    @classmethod
    def build(
        cls, root: str, contracts, cache: Optional[ModuleCache] = None,
        paths: Optional[Sequence[str]] = None,
    ) -> "Program":
        """Index every .py under ``contracts.GRAPH_SCOPE`` (or an
        explicit ``paths`` list of repo-relative files) and extract the
        call graph."""
        cache = cache or ModuleCache(root)
        prog = cls(root, contracts, cache)
        if paths is None:
            paths = []
            for prefix in contracts.GRAPH_SCOPE:
                base = os.path.join(root, prefix)
                for dirpath, dirnames, filenames in os.walk(base):
                    dirnames[:] = sorted(
                        d for d in dirnames
                        if d != "__pycache__" and not d.startswith(".")
                    )
                    for name in sorted(filenames):
                        if name.endswith(".py"):
                            full = os.path.join(dirpath, name)
                            paths.append(
                                os.path.relpath(full, root).replace(
                                    os.sep, "/"
                                )
                            )
        for rel in paths:
            try:
                mod = prog.cache.get(rel)
            except SyntaxError as e:
                prog.broken[rel] = str(e)
                continue
            prog._index_module(rel, mod)
        prog._link_classes()
        for mi in prog.modules.values():
            prog._extract_edges(mi)
        return prog

    def _index_module(self, rel: str, mod: Module) -> None:
        mi = ModuleInfo(
            path=rel, module=mod, imports=ImportMap(mod.tree),
            mod_name=_mod_name(rel),
        )
        self.modules[rel] = mi
        for stmt in mod.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._index_function(mi, stmt, prefix="", cls=None)
            elif isinstance(stmt, ast.ClassDef):
                ci = ClassInfo(
                    path=rel, name=stmt.name, node=stmt,
                    base_exprs=list(stmt.bases),
                )
                for dec in stmt.decorator_list:
                    d = self._decorator_name(dec, mi)
                    if d == self.contracts.POLICY_DECORATOR:
                        ci.is_registered_policy = True
                for sub in stmt.body:
                    if isinstance(
                        sub, (ast.FunctionDef, ast.AsyncFunctionDef)
                    ):
                        fi = self._index_function(
                            mi, sub, prefix=f"{stmt.name}.", cls=stmt.name
                        )
                        ci.methods[sub.name] = fi
                mi.classes[stmt.name] = ci
                self.classes.append(ci)
                self._by_mod_class[(mi.mod_name, stmt.name)] = ci
        # module pseudo-node for top-level code
        self.edges.setdefault(node_id(rel, MODULE_NODE), set())

    def _index_function(
        self, mi: ModuleInfo, node, prefix: str, cls: Optional[str]
    ) -> FunctionInfo:
        qual = f"{prefix}{node.name}"
        fi = FunctionInfo(
            node_id=node_id(mi.path, qual), path=mi.path, qualname=qual,
            name=node.name, node=node, cls=cls,
            params=[a.arg for a in node.args.posonlyargs
                    + node.args.args + node.args.kwonlyargs],
        )
        self.functions[fi.node_id] = fi
        self._by_astnode[id(node)] = fi
        if cls is None and prefix == "":
            mi.functions[node.name] = fi
            self._by_mod_func[(mi.mod_name, node.name)] = fi
        self.edges.setdefault(fi.node_id, set())
        # nested defs: indexed under "outer.inner" with an implicit
        # containment edge (the closure a factory returns is reachable
        # exactly when the factory is).
        for sub in ast.walk(node):
            if sub is node:
                continue
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                sub_id = node_id(mi.path, f"{qual}.{sub.name}")
                if sub_id in self.functions:   # name collision at depth
                    sub_id = f"{sub_id}@{sub.lineno}"
                sub_fi = FunctionInfo(
                    node_id=sub_id, path=mi.path,
                    qualname=sub_id.split("::", 1)[1],
                    name=sub.name, node=sub, cls=cls,
                    params=[a.arg for a in sub.args.posonlyargs
                            + sub.args.args + sub.args.kwonlyargs],
                )
                self.functions[sub_fi.node_id] = sub_fi
                self._by_astnode[id(sub)] = sub_fi
                self.edges.setdefault(fi.node_id, set()).add(
                    sub_fi.node_id
                )
                self.edges.setdefault(sub_fi.node_id, set())
        return fi

    def _decorator_name(self, dec: ast.expr, mi: ModuleInfo) -> str:
        """Last path segment of a decorator expression (unwrapping
        calls like ``@functools.partial(jit, ...)`` to their callee)."""
        if isinstance(dec, ast.Call):
            dec = dec.func
        d = dotted(dec, mi.imports)
        if d:
            return d.rsplit(".", 1)[-1]
        if isinstance(dec, ast.Name):
            return dec.id
        if isinstance(dec, ast.Attribute):
            return dec.attr
        return ""

    def _link_classes(self) -> None:
        for ci in self.classes:
            mi = self.modules[ci.path]
            for expr in ci.base_exprs:
                base = self._resolve_class_expr(expr, mi)
                if base is not None:
                    ci.bases.append(base)
        # subclass index (transitive, via repeated direct expansion)
        direct: Dict[int, List[ClassInfo]] = {}
        for ci in self.classes:
            for b in ci.bases:
                direct.setdefault(id(b), []).append(ci)
        for ci in self.classes:
            seen: List[ClassInfo] = []
            stack = list(direct.get(id(ci), []))
            while stack:
                c = stack.pop()
                if c not in seen:
                    seen.append(c)
                    stack.extend(direct.get(id(c), []))
            self._subclasses[id(ci)] = seen
        # the policy registry
        reg = getattr(self.contracts, "POLICY_REGISTRY", None)
        if reg:
            base = None
            for ci in self.classes:
                if ci.path == reg["module"] and ci.name == reg["base"]:
                    base = ci
                    break
            self._policy_base = base
            self._policy_classes = [
                c for c in self.classes if c.is_registered_policy
            ]

    def _resolve_class_expr(
        self, expr: ast.expr, mi: ModuleInfo
    ) -> Optional[ClassInfo]:
        """Resolve a Name/Attribute (or string annotation) to a program
        class, through the module's imports."""
        if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
            try:
                expr = ast.parse(expr.value, mode="eval").body
            except SyntaxError:
                return None
        if isinstance(expr, ast.Subscript):   # Optional[X] / List[X]
            return None
        if isinstance(expr, ast.Name) and expr.id in mi.classes:
            return mi.classes[expr.id]
        d = dotted(expr, mi.imports)
        if d is None:
            return None
        head, _, tail = d.rpartition(".")
        return self._by_mod_class.get((head, tail))

    # ------------------------------------------------------------- #
    # Symbol lookup
    # ------------------------------------------------------------- #

    def lookup_dotted(self, d: str, _depth: int = 0):
        """``repro.core.policy.resolve_policy`` -> FunctionInfo /
        ClassInfo / None (external or unresolved)."""
        head, _, tail = d.rpartition(".")
        fi = self._by_mod_func.get((head, tail))
        if fi is not None:
            return fi
        ci = self._by_mod_class.get((head, tail))
        if ci is not None:
            return ci
        # Cls.method / module attribute chains: try one level up.
        h2, _, mid = head.rpartition(".")
        ci = self._by_mod_class.get((h2, mid))
        if ci is not None:
            return ci.find_method(tail)
        # Re-export: `from repro.core import waterfill_counts` where the
        # package __init__ merely re-imports the symbol.  Follow one hop
        # through the exporting module's own imports.
        if _depth < 4:
            for mi in self.modules.values():
                if mi.mod_name == head:
                    target = mi.imports.names.get(tail)
                    if target is not None and target != d:
                        return self.lookup_dotted(target, _depth + 1)
                    break
        return None

    def is_program_name(self, d: str) -> bool:
        """Does this dotted path point INTO the indexed tree (even if
        the symbol itself did not resolve)?  Unresolved program-internal
        callees degrade to UNKNOWN; external libraries do not."""
        if self._prog_roots is None:
            self._prog_roots = {
                mi.mod_name.split(".", 1)[0]
                for mi in self.modules.values()
            }
        return d.split(".", 1)[0] in self._prog_roots

    def subclasses(self, ci: ClassInfo) -> List[ClassInfo]:
        return self._subclasses.get(id(ci), [])

    @property
    def policy_classes(self) -> List[ClassInfo]:
        return list(self._policy_classes)

    @property
    def policy_base(self) -> Optional[ClassInfo]:
        return self._policy_base

    def _is_policy_class(self, ci: ClassInfo) -> bool:
        if ci.is_registered_policy:
            return True
        base = self._policy_base
        return base is not None and (
            ci is base or base in ci.mro()
        )

    # ------------------------------------------------------------- #
    # Local type inference
    # ------------------------------------------------------------- #

    def _class_attr_types(self, ci: ClassInfo) -> Dict[str, Tuple]:
        if ci.attr_types:
            return ci.attr_types
        # Re-entrancy guard: inferring an attribute's type can ask for
        # the same class's attribute table (self.x = self._make_x()).
        if id(ci) in self._attrs_in_progress:
            return {}
        self._attrs_in_progress.add(id(ci))
        mi = self.modules[ci.path]
        types: Dict[str, Tuple] = {}
        # class-body annotations (dataclass fields included)
        for stmt in ci.node.body:
            if isinstance(stmt, ast.AnnAssign) and isinstance(
                stmt.target, ast.Name
            ):
                t = self._annotation_type(stmt.annotation, mi)
                if t is not None:
                    types[stmt.target.id] = t
        # __init__ assignments: self.x = <param annotated C> / C(...)
        init = ci.methods.get("__init__")
        if init is not None:
            env = self._param_types(init, mi)
            for stmt in ast.walk(init.node):
                if isinstance(stmt, ast.Assign):
                    for tgt in stmt.targets:
                        if (
                            isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"
                        ):
                            t = self._expr_type(stmt.value, mi, env, ci)
                            if t is not None:
                                types.setdefault(tgt.attr, t)
        for base in ci.bases:
            for k, v in self._class_attr_types(base).items():
                types.setdefault(k, v)
        self._attrs_in_progress.discard(id(ci))
        ci.attr_types = types
        return types

    def _annotation_type(
        self, ann: ast.expr, mi: ModuleInfo
    ) -> Optional[Tuple]:
        c = self._resolve_class_expr(ann, mi)
        if c is None:
            return None
        if self._is_policy_class(c):
            return _POLICY
        return ("class", c)

    def _param_types(
        self, fi: FunctionInfo, mi: ModuleInfo
    ) -> Dict[str, Tuple]:
        env: Dict[str, Tuple] = {}
        args = fi.node.args
        for a in args.args + args.posonlyargs + args.kwonlyargs:
            if a.annotation is not None:
                t = self._annotation_type(a.annotation, mi)
                if t is not None:
                    env[a.arg] = t
        return env

    def _expr_type(
        self, expr: ast.expr, mi: ModuleInfo, env: Dict[str, Tuple],
        ci: Optional[ClassInfo],
    ) -> Optional[Tuple]:
        """Best-effort static type of an expression (None = unknown)."""
        reg = getattr(self.contracts, "POLICY_REGISTRY", None)
        if isinstance(expr, ast.Name):
            if expr.id == "self" and ci is not None:
                return ("class", ci)
            if expr.id in env:
                return env[expr.id]
            if expr.id in mi.classes:
                c = mi.classes[expr.id]
                return _POLICY_REF if self._is_policy_class(c) else (
                    "classref", c
                )
            d = dotted(expr, mi.imports)
            if d:
                sym = self.lookup_dotted(d)
                if isinstance(sym, ClassInfo):
                    return _POLICY_REF if self._is_policy_class(sym) else (
                        "classref", sym
                    )
            return None
        if isinstance(expr, ast.Attribute):
            base_t = self._expr_type(expr.value, mi, env, ci)
            if base_t is not None and base_t[0] == "class":
                at = self._class_attr_types(base_t[1]).get(expr.attr)
                return at
            d = dotted(expr, mi.imports)
            if d:
                sym = self.lookup_dotted(d)
                if isinstance(sym, ClassInfo):
                    return _POLICY_REF if self._is_policy_class(sym) else (
                        "classref", sym
                    )
            return None
        if isinstance(expr, ast.Call):
            # super(): methods resolve through the first program base
            if (
                isinstance(expr.func, ast.Name)
                and expr.func.id == "super"
                and ci is not None
            ):
                return ("class", ci.bases[0]) if ci.bases else None
            # constructor?
            t = self._expr_type(expr.func, mi, env, ci)
            if t is not None:
                if t[0] == "classref":
                    c = t[1]
                    return _POLICY if self._is_policy_class(c) else (
                        "class", c
                    )
                if t[0] == "policyref":
                    return _POLICY
            # registry factory?
            if reg:
                fname = None
                if isinstance(expr.func, ast.Name):
                    fname = expr.func.id
                elif isinstance(expr.func, ast.Attribute):
                    fname = expr.func.attr
                if fname in reg["factories"]:
                    if fname == "resolve_policy" or fname == "policy_class":
                        return _POLICY_REF
                    return _POLICY
            # return-annotation inference: f(...) where f's def carries
            # `-> C` for a program class C
            target = self._resolve_callable(expr.func, mi, env, ci)
            if isinstance(target, FunctionInfo):
                returns = getattr(target.node, "returns", None)
                if returns is not None:
                    t_mi = self.modules.get(target.path, mi)
                    return self._annotation_type(returns, t_mi)
            return None
        if isinstance(expr, (ast.List, ast.Tuple)):
            elts = [self._expr_type(e, mi, env, ci) for e in expr.elts]
            elts = [t for t in elts if t is not None]
            if elts and all(t == elts[0] for t in elts):
                return ("seq", elts[0])
            return None
        if isinstance(expr, ast.ListComp):
            t = self._expr_type(expr.elt, mi, env, ci)
            return ("seq", t) if t is not None else None
        if isinstance(expr, ast.Subscript):
            base_t = self._expr_type(expr.value, mi, env, ci)
            if base_t is not None and base_t[0] == "seq":
                return base_t[1]
            return None
        return None

    # ------------------------------------------------------------- #
    # Edge extraction
    # ------------------------------------------------------------- #

    def _extract_edges(self, mi: ModuleInfo) -> None:
        owner_module = node_id(mi.path, MODULE_NODE)

        # Ownership: every call/reference belongs to its innermost
        # enclosing function (the module pseudo-node otherwise).
        def visit(body_owner: str, node: ast.AST,
                  env: Dict[str, Tuple], ci: Optional[ClassInfo]) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    # switch owner; env = closure variables (nested defs
                    # see the enclosing scope) minus shadowing params,
                    # plus the def's own annotated params
                    fi = self._by_astnode.get(id(child))
                    a = child.args
                    shadow = {p.arg for p in a.posonlyargs + a.args
                              + a.kwonlyargs}
                    sub_env = {
                        k: v for k, v in env.items() if k not in shadow
                    }
                    if fi is not None:
                        sub_env.update(self._param_types(fi, mi))
                        # later siblings can call/reference this def
                        env[child.name] = ("funcref", fi)
                    owner = fi.node_id if fi is not None else body_owner
                    for dec in child.decorator_list:
                        visit(body_owner, dec, env, ci)
                        self._reference(body_owner, dec, mi, env, ci)
                    visit(owner, child, sub_env, ci)
                    continue
                if isinstance(child, ast.ClassDef):
                    new_ci = mi.classes.get(child.name, ci)
                    for dec in child.decorator_list:
                        self._reference(body_owner, dec, mi, env, ci)
                    visit(body_owner, child, {}, new_ci)
                    continue
                if isinstance(child, ast.Call):
                    self._call_edge(body_owner, child, mi, env, ci)
                    visit(body_owner, child, env, ci)
                    continue
                if isinstance(child, (ast.Name, ast.Attribute)):
                    # Non-call reference to a program function (callback,
                    # partial argument, heap payload) = may-call edge.
                    target = self._resolve_callable(
                        child, mi, env, ci, as_callee=False
                    )
                    if isinstance(target, FunctionInfo):
                        self.edges.setdefault(body_owner, set()).add(
                            target.node_id
                        )
                    visit(body_owner, child, env, ci)
                    continue
                if isinstance(child, ast.Assign):
                    t = self._expr_type(child.value, mi, env, ci)
                    if t is not None:
                        for tgt in child.targets:
                            if isinstance(tgt, ast.Name):
                                env[tgt.id] = t
                elif isinstance(child, ast.AnnAssign) and isinstance(
                    child.target, ast.Name
                ):
                    t = self._annotation_type(child.annotation, mi)
                    if t is not None:
                        env[child.target.id] = t
                visit(body_owner, child, env, ci)

        visit(owner_module, mi.module.tree, {}, None)

    def _reference(
        self, owner: str, expr: ast.expr, mi: ModuleInfo,
        env: Dict[str, Tuple], ci: Optional[ClassInfo],
    ) -> None:
        """Reference edge for a decorator expression."""
        node = expr.func if isinstance(expr, ast.Call) else expr
        target = self._resolve_callable(
            node, mi, env, ci, as_callee=False
        )
        if isinstance(target, FunctionInfo):
            self.edges.setdefault(owner, set()).add(target.node_id)

    def _resolve_callable(
        self, func: ast.expr, mi: ModuleInfo, env: Dict[str, Tuple],
        ci: Optional[ClassInfo], as_callee: bool = True,
    ):
        """Resolve a callee expression.  Returns a FunctionInfo, a
        ClassInfo (constructor), a list of FunctionInfos (dynamic
        dispatch fan-out), "external", or None (unresolvable)."""
        if isinstance(func, ast.Name):
            name = func.id
            if name in env:
                return self._typed_value_call(env[name], None)
            # local class / function in this module
            if name in mi.classes:
                return mi.classes[name]
            if name in mi.functions:
                return mi.functions[name]
            d = dotted(func, mi.imports)
            if d is not None:
                sym = self.lookup_dotted(d)
                if sym is not None:
                    return sym
                return None if self.is_program_name(d) else "external"
            if name in _BUILTIN_NAMES:
                return "external"
            return None
        if isinstance(func, ast.Attribute):
            # full dotted import chain (module.func / module.Cls)
            d = dotted(func, mi.imports)
            if d is not None:
                sym = self.lookup_dotted(d)
                if sym is not None:
                    return sym
                return None if self.is_program_name(d) else "external"
            recv_t = self._expr_type(func.value, mi, env, ci)
            if recv_t is not None:
                return self._typed_value_call(recv_t, func.attr)
            return None
        if isinstance(func, ast.Call) and as_callee:
            # calling a call's result: `resolve_policy(kind)(cfg, ctx)`
            t = self._expr_type(func, mi, env, ci)
            if t is not None:
                return self._typed_value_call(t, None)
            return None
        if isinstance(func, ast.Subscript) and as_callee:
            # `policies[q](...)` — a callable out of a typed sequence
            t = self._expr_type(func, mi, env, ci)
            if t is not None:
                return self._typed_value_call(t, None)
            return None
        return None

    def _typed_value_call(self, t: Tuple, attr: Optional[str]):
        """Call/method-call through a typed value."""
        if t[0] == "class":
            target = t[1]
            if attr is None:
                return None          # calling an instance: __call__?
            m = target.find_method(attr)
            if m is None:
                if self._is_policy_class(target):
                    return self._policy_method_fanout(attr)
                return None
            out = [m]
            for sub in self.subclasses(target):
                if attr in sub.methods and sub.methods[attr] is not m:
                    out.append(sub.methods[attr])
            return out
        if t[0] == "policy":
            if attr is None:
                return None
            return self._policy_method_fanout(attr)
        if t[0] == "classref":
            if attr is None:
                return t[1]          # construction
            m = t[1].find_method(attr)
            return m
        if t[0] == "funcref":
            return t[1] if attr is None else None
        if t[0] == "policyref":
            if attr is None:
                # constructing "some registered policy"
                out = []
                for c in self._policy_fanout_classes():
                    init = c.find_method("__init__")
                    if init is not None and init not in out:
                        out.append(init)
                return out or None
            return self._policy_method_fanout(attr)
        return None

    def _policy_fanout_classes(self) -> List[ClassInfo]:
        out = list(self._policy_classes)
        if self._policy_base is not None and self._policy_base not in out:
            out.append(self._policy_base)
        return out

    def _policy_method_fanout(self, attr: str):
        out: List[FunctionInfo] = []
        for c in self._policy_fanout_classes():
            m = c.find_method(attr)
            if m is not None and m not in out:
                out.append(m)
        return out or None

    def _call_edge(
        self, owner: str, call: ast.Call, mi: ModuleInfo,
        env: Dict[str, Tuple], ci: Optional[ClassInfo],
    ) -> None:
        target = self._resolve_callable(call.func, mi, env, ci)
        edges = self.edges.setdefault(owner, set())
        if target is None:
            edges.add(UNKNOWN)
            return
        if target == "external":
            return
        if isinstance(target, ClassInfo):
            # constructor: __init__ + __post_init__ through the MRO
            hit = False
            for name in ("__init__", "__post_init__"):
                m = target.find_method(name)
                if m is not None:
                    edges.add(m.node_id)
                    hit = True
            if not hit:
                # plain dataclass/namedtuple construction: no user code
                pass
            return
        if isinstance(target, FunctionInfo):
            edges.add(target.node_id)
            return
        if isinstance(target, list):
            for fi in target:
                edges.add(fi.node_id)
            return
        edges.add(UNKNOWN)

    # ------------------------------------------------------------- #
    # Reachability
    # ------------------------------------------------------------- #

    def resolve_root(self, root: str) -> Optional[str]:
        """A ``path::Qual.name`` pin root -> node id (validated)."""
        return root if root in self.functions else None

    def closure(self, roots: Iterable[str]) -> Set[str]:
        """Forward reachability over the call graph from ``roots``
        (node ids).  The result may contain :data:`UNKNOWN`."""
        seen: Set[str] = set()
        stack = [r for r in roots]
        while stack:
            n = stack.pop()
            if n in seen:
                continue
            seen.add(n)
            for callee in self.edges.get(n, ()):
                if callee not in seen:
                    stack.append(callee)
        return seen


#: Builtin callables: calls to these are external, not UNKNOWN.
_BUILTIN_NAMES = frozenset((
    "super", "slice", "memoryview", "bytes", "bytearray", "complex",
    "object", "staticmethod", "classmethod", "property", "callable",
    "exec", "eval", "compile", "globals", "locals", "delattr", "input",
    "print", "len", "range", "enumerate", "zip", "map", "filter",
    "sorted", "reversed", "min", "max", "sum", "abs", "round", "int",
    "float", "bool", "str", "repr", "list", "tuple", "dict", "set",
    "frozenset", "isinstance", "issubclass", "getattr", "setattr",
    "hasattr", "iter", "next", "open", "type", "id", "hash", "vars",
    "any", "all", "divmod", "pow", "format", "ord", "chr",
))
