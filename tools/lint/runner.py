"""dyslint CLI — run the invariant passes over the tree.

Usage (see ``make lint``)::

    python tools/lint/runner.py                  # src/ tools/ benchmarks/
    python tools/lint/runner.py path [path ...]  # explicit scope
    python tools/lint/runner.py --list-codes
    python tools/lint/runner.py --update-baseline

Exit status: 0 when every finding is inline-suppressed
(``# dyslint: disable=CODE -- reason``) or grandfathered in
``tools/lint/baseline.json``; 1 when new findings exist; 2 on usage
errors.  The contract layer is loaded straight from
``src/repro/core/contracts.py`` (no ``repro`` import, no numpy/jax),
so linting runs on a bare Python.
"""

from __future__ import annotations

import argparse
import importlib.util
import os
import sys
from typing import Dict, List, Sequence, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)
)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools.lint import (  # noqa: E402
    Finding,
    Module,
    dump_baseline,
    load_baseline,
    split_baselined,
    split_suppressed,
)
from tools.lint.passes import ALL_PASSES, all_codes  # noqa: E402

_CONTRACTS_PATH = os.path.join(_ROOT, "src", "repro", "core", "contracts.py")
_BASELINE_PATH = os.path.join(_ROOT, "tools", "lint", "baseline.json")

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules"}


def load_contracts(path: str = _CONTRACTS_PATH):
    """Load the contract layer standalone (without importing the
    ``repro.core`` package, which would pull in numpy/jax)."""
    spec = importlib.util.spec_from_file_location("_dyslint_contracts", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def discover(paths: Sequence[str]) -> List[str]:
    """Expand files/directories into a sorted list of .py files."""
    out: List[str] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(_ROOT, p)
        if os.path.isfile(full):
            out.append(full)
        elif os.path.isdir(full):
            for dirpath, dirnames, filenames in os.walk(full):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in _SKIP_DIRS and not d.startswith(".")
                )
                for name in sorted(filenames):
                    if name.endswith(".py"):
                        out.append(os.path.join(dirpath, name))
        else:
            raise FileNotFoundError(p)
    return out


def lint_file(
    full_path: str, contracts
) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Lint one file.  Returns (active, suppressed, source_lines)."""
    rel = os.path.relpath(full_path, _ROOT).replace(os.sep, "/")
    with open(full_path, encoding="utf-8") as fh:
        text = fh.read()
    try:
        module = Module.from_source(rel, text)
    except SyntaxError as e:
        f = Finding(
            code="DY001", path=rel, line=e.lineno or 1, col=e.offset or 0,
            message=f"file does not parse: {e.msg}",
        )
        return [f], [], text.splitlines()
    findings: List[Finding] = []
    for p in ALL_PASSES:
        if p.applies(rel, contracts):
            findings.extend(p.run(module, contracts))
    findings.sort(key=lambda f: (f.line, f.col, f.code))
    return (*split_suppressed(findings, module.lines), module.lines)


def lint_paths(
    paths: Sequence[str], contracts
) -> Tuple[List[Finding], List[Finding], Dict[str, List[str]]]:
    """Lint many paths.  Returns (active, suppressed, lines_by_path)."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    lines_by_path: Dict[str, List[str]] = {}
    for full in discover(paths):
        a, s, lines = lint_file(full, contracts)
        rel = os.path.relpath(full, _ROOT).replace(os.sep, "/")
        lines_by_path[rel] = lines
        active.extend(a)
        suppressed.extend(s)
    return active, suppressed, lines_by_path


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="dyslint", description=__doc__)
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: the "
                         "contract layer's DEFAULT_LINT_PATHS)")
    ap.add_argument("--baseline", default=_BASELINE_PATH,
                    help="grandfathered-findings file")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report grandfathered findings too")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current "
                         "UN-suppressed findings and exit 0")
    ap.add_argument("--list-codes", action="store_true")
    args = ap.parse_args(argv)

    if args.list_codes:
        for p in ALL_PASSES:
            print(f"[{p.NAME}]")
            for code, desc in sorted(p.CODES.items()):
                print(f"  {code}  {desc}")
        return 0

    contracts = load_contracts()
    paths = args.paths or list(contracts.DEFAULT_LINT_PATHS)
    try:
        active, suppressed, lines_by_path = lint_paths(paths, contracts)
    except FileNotFoundError as e:
        print(f"dyslint: no such path: {e}", file=sys.stderr)
        return 2

    if args.update_baseline:
        with open(args.baseline, "w", encoding="utf-8") as fh:
            fh.write(dump_baseline(active, lines_by_path))
        print(f"dyslint: baseline rewritten with {len(active)} "
              f"finding(s) -> {args.baseline}")
        return 0

    baselined: List[Finding] = []
    stale = 0
    if not args.no_baseline and os.path.isfile(args.baseline):
        baseline = load_baseline(args.baseline)
        active, baselined, stale = split_baselined(
            active, baseline, lines_by_path
        )

    for f in active:
        print(f.render())
    known = all_codes()
    n_files = len(lines_by_path)
    summary = (
        f"dyslint: {len(active)} finding(s) "
        f"({len(suppressed)} suppressed, {len(baselined)} baselined) "
        f"across {n_files} file(s), {len(known)} codes"
    )
    if stale:
        summary += (
            f"; {stale} stale baseline entr"
            f"{'y' if stale == 1 else 'ies'} — run --update-baseline"
        )
    print(summary)
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
