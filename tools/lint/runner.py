"""dyslint CLI — run the invariant passes over the tree.

Usage (see ``make lint``)::

    python tools/lint/runner.py                  # src/ tools/ benchmarks/
    python tools/lint/runner.py path [path ...]  # explicit scope
    python tools/lint/runner.py --jobs 0         # parallel (auto width)
    python tools/lint/runner.py --list-codes
    python tools/lint/runner.py --update-baseline
    python tools/lint/runner.py --write-pin-map  # regen pin_map.json

Two kinds of passes run:

  * the per-module passes (DY1xx–DY4xx) — one file at a time, parsed
    once into a shared :class:`tools.lint.graph.ModuleCache` and
    parallelizable with ``--jobs N`` (0 = one worker per core);
  * the dyflow program passes (DY5xx units, DY6xx pin impact) — run
    once over the whole-program call graph built from that same cache,
    with findings filtered to the requested scope.

Exit status: 0 when every finding is inline-suppressed
(``# dyslint: disable=CODE -- reason``) or grandfathered in
``tools/lint/baseline.json``; 1 when new findings exist; 2 on usage
errors.  The contract layer is loaded straight from
``src/repro/core/contracts.py`` (no ``repro`` import, no numpy/jax),
so linting runs on a bare Python.
"""

from __future__ import annotations

import argparse
import importlib.util
import multiprocessing
import os
import sys
import time
from typing import Dict, List, Sequence, Set, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)
)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools.lint import (  # noqa: E402
    Finding,
    Module,
    dump_baseline,
    load_baseline,
    split_baselined,
    split_suppressed,
)
from tools.lint.graph import ModuleCache, Program  # noqa: E402
from tools.lint.passes import (  # noqa: E402
    ALL_PASSES,
    PROGRAM_PASSES,
    all_codes,
    pin_impact,
)

_CONTRACTS_PATH = os.path.join(_ROOT, "src", "repro", "core", "contracts.py")
_BASELINE_PATH = os.path.join(_ROOT, "tools", "lint", "baseline.json")

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules"}


def load_contracts(path: str = _CONTRACTS_PATH):
    """Load the contract layer standalone (without importing the
    ``repro.core`` package, which would pull in numpy/jax)."""
    spec = importlib.util.spec_from_file_location("_dyslint_contracts", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def discover(paths: Sequence[str]) -> List[str]:
    """Expand files/directories into a sorted list of .py files."""
    out: List[str] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(_ROOT, p)
        if os.path.isfile(full):
            out.append(full)
        elif os.path.isdir(full):
            for dirpath, dirnames, filenames in os.walk(full):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in _SKIP_DIRS and not d.startswith(".")
                )
                for name in sorted(filenames):
                    if name.endswith(".py"):
                        out.append(os.path.join(dirpath, name))
        else:
            raise FileNotFoundError(p)
    return out


def lint_file(
    full_path: str, contracts, cache: ModuleCache | None = None
) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Lint one file with the per-module passes.  Returns
    (active, suppressed, source_lines)."""
    rel = os.path.relpath(full_path, _ROOT).replace(os.sep, "/")
    try:
        if cache is not None:
            module = cache.get(rel)
        else:
            with open(full_path, encoding="utf-8") as fh:
                text = fh.read()
            module = Module.from_source(rel, text)
    except SyntaxError as e:
        f = Finding(
            code="DY001", path=rel, line=e.lineno or 1, col=e.offset or 0,
            message=f"file does not parse: {e.msg}",
        )
        with open(full_path, encoding="utf-8") as fh:
            return [f], [], fh.read().splitlines()
    findings: List[Finding] = []
    for p in ALL_PASSES:
        if p.applies(rel, contracts):
            findings.extend(p.run(module, contracts))
    findings.sort(key=lambda f: (f.line, f.col, f.code))
    return (*split_suppressed(findings, module.lines), module.lines)


# One contracts load per pool worker (module objects don't pickle).
_WORKER_CONTRACTS = None


def _worker_init() -> None:
    global _WORKER_CONTRACTS
    _WORKER_CONTRACTS = load_contracts()


def _worker_lint(full_path: str):
    return lint_file(full_path, _WORKER_CONTRACTS)


def lint_paths(
    paths: Sequence[str], contracts, jobs: int = 1,
    cache: ModuleCache | None = None,
) -> Tuple[List[Finding], List[Finding], Dict[str, List[str]]]:
    """Per-module passes over many paths (``jobs`` parallel workers;
    0 = one per core).  Returns (active, suppressed, lines_by_path)."""
    files = discover(paths)
    active: List[Finding] = []
    suppressed: List[Finding] = []
    lines_by_path: Dict[str, List[str]] = {}
    if jobs != 1 and len(files) > 1:
        with multiprocessing.Pool(
            jobs or None, initializer=_worker_init
        ) as pool:
            results = pool.map(_worker_lint, files)
    else:
        results = [lint_file(f, contracts, cache) for f in files]
    for full, (a, s, lines) in zip(files, results):
        rel = os.path.relpath(full, _ROOT).replace(os.sep, "/")
        lines_by_path[rel] = lines
        active.extend(a)
        suppressed.extend(s)
    return active, suppressed, lines_by_path


def run_program_passes(
    rel_files: Set[str], contracts, cache: ModuleCache,
    explicit_files: Sequence[str] = (),
) -> Tuple[List[Finding], List[Finding]]:
    """The dyflow program passes, filtered to the linted scope (so a
    single-file lint of a fixture is not spammed with whole-tree
    findings).  ``explicit_files`` — files named individually on the
    command line — are units-checked even outside ``UNITS_SCOPE``
    (fixtures); directory sweeps never widen the scope.  Skipped
    entirely when nothing touches the program surface."""
    prefixes = tuple(contracts.GRAPH_SCOPE) + tuple(contracts.UNITS_SCOPE)
    extras = tuple(
        rel for rel in explicit_files if not rel.startswith(prefixes)
    )
    if not extras and not any(
        rel.startswith(prefixes) for rel in rel_files
    ):
        return [], []
    program = Program.build(_ROOT, contracts, cache)
    findings: List[Finding] = []
    for p in PROGRAM_PASSES:
        findings.extend(p.run_program(program, contracts, extras))
    findings = [f for f in findings if f.path in rel_files]
    active: List[Finding] = []
    suppressed: List[Finding] = []
    by_path: Dict[str, List[Finding]] = {}
    for f in findings:
        by_path.setdefault(f.path, []).append(f)
    for path, fs in by_path.items():
        try:
            lines = cache.get(path).lines
        except (OSError, SyntaxError):
            active.extend(fs)
            continue
        a, s = split_suppressed(fs, lines)
        active.extend(a)
        suppressed.extend(s)
    return active, suppressed


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="dyslint", description=__doc__)
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: the "
                         "contract layer's DEFAULT_LINT_PATHS)")
    ap.add_argument("--jobs", type=int, default=1, metavar="N",
                    help="parallel lint workers (0 = one per core; "
                         "default 1)")
    ap.add_argument("--baseline", default=_BASELINE_PATH,
                    help="grandfathered-findings file")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report grandfathered findings too")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current "
                         "UN-suppressed findings and exit 0")
    ap.add_argument("--write-pin-map", action="store_true",
                    help="recompute the pin-impact closures and "
                         "rewrite tools/lint/pin_map.json")
    ap.add_argument("--list-codes", action="store_true")
    args = ap.parse_args(argv)

    if args.list_codes:
        for p in ALL_PASSES + PROGRAM_PASSES:
            print(f"[{p.NAME}]")
            for code, desc in sorted(p.CODES.items()):
                print(f"  {code}  {desc}")
        return 0

    t0 = time.perf_counter()
    contracts = load_contracts()
    cache = ModuleCache(_ROOT)

    if args.write_pin_map:
        program = Program.build(_ROOT, contracts, cache)
        pin_map = pin_impact.compute_pin_map(program, contracts)
        out_path = os.path.join(_ROOT, contracts.PIN_MAP_PATH)
        with open(out_path, "w", encoding="utf-8") as fh:
            fh.write(pin_impact.dump_pin_map(pin_map))
        n = sum(len(p["functions"]) for p in pin_map["pins"].values())
        print(f"dyslint: pin map rewritten ({len(pin_map['pins'])} "
              f"pin(s), {n} function entries) -> "
              f"{contracts.PIN_MAP_PATH}")
        return 0

    paths = args.paths or list(contracts.DEFAULT_LINT_PATHS)
    try:
        active, suppressed, lines_by_path = lint_paths(
            paths, contracts, jobs=args.jobs, cache=cache
        )
    except FileNotFoundError as e:
        print(f"dyslint: no such path: {e}", file=sys.stderr)
        return 2
    explicit_files = tuple(
        os.path.relpath(
            p if os.path.isabs(p) else os.path.join(_ROOT, p), _ROOT
        ).replace(os.sep, "/")
        for p in paths
        if os.path.isfile(p if os.path.isabs(p)
                          else os.path.join(_ROOT, p))
        and p.endswith(".py")
    )
    prog_active, prog_suppressed = run_program_passes(
        set(lines_by_path), contracts, cache, explicit_files
    )
    active.extend(prog_active)
    suppressed.extend(prog_suppressed)
    active.sort(key=lambda f: (f.path, f.line, f.col, f.code))

    if args.update_baseline:
        with open(args.baseline, "w", encoding="utf-8") as fh:
            fh.write(dump_baseline(active, lines_by_path))
        print(f"dyslint: baseline rewritten with {len(active)} "
              f"finding(s) -> {args.baseline}")
        return 0

    baselined: List[Finding] = []
    stale = 0
    if not args.no_baseline and os.path.isfile(args.baseline):
        baseline = load_baseline(args.baseline)
        active, baselined, stale = split_baselined(
            active, baseline, lines_by_path
        )

    for f in active:
        print(f.render())
    known = all_codes()
    n_files = len(lines_by_path)
    wall = time.perf_counter() - t0
    summary = (
        f"dyslint: {len(active)} finding(s) "
        f"({len(suppressed)} suppressed, {len(baselined)} baselined) "
        f"across {n_files} file(s), {len(known)} codes in {wall:.2f}s"
    )
    if stale:
        summary += (
            f"; {stale} stale baseline entr"
            f"{'y' if stale == 1 else 'ies'} — run --update-baseline"
        )
    print(summary)
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
