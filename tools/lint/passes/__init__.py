"""The dyslint passes.  Each PER-MODULE pass exports:

  * ``NAME``   — short pass name for ``--list-codes`` output;
  * ``CODES``  — {code: one-line description};
  * ``applies(relpath, contracts) -> bool`` — scope predicate;
  * ``run(module, contracts) -> list[Finding]``.

The dyflow PROGRAM passes (``PROGRAM_PASSES``) see the whole tree at
once instead: they export ``run_program(program, contracts)`` taking
the interprocedural :class:`tools.lint.graph.Program`, and the runner
invokes them once per lint run (never per file), filtering their
findings to the requested scope.
"""

from __future__ import annotations

from tools.lint.passes import (  # noqa: F401
    capability,
    determinism,
    float_order,
    jax_hazard,
    pin_impact,
    units,
)

ALL_PASSES = (determinism, capability, jax_hazard, float_order)

PROGRAM_PASSES = (units, pin_impact)


def all_codes() -> dict:
    out = {}
    for p in ALL_PASSES + PROGRAM_PASSES:
        out.update(p.CODES)
    return out
