"""The dyslint passes.  Each pass module exports:

  * ``NAME``   — short pass name for ``--list-codes`` output;
  * ``CODES``  — {code: one-line description};
  * ``applies(relpath, contracts) -> bool`` — scope predicate;
  * ``run(module, contracts) -> list[Finding]``.
"""

from __future__ import annotations

from tools.lint.passes import (  # noqa: F401
    capability,
    determinism,
    float_order,
    jax_hazard,
)

ALL_PASSES = (determinism, capability, jax_hazard, float_order)


def all_codes() -> dict:
    out = {}
    for p in ALL_PASSES:
        out.update(p.CODES)
    return out
