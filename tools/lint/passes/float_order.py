"""DY4xx — float-op order: bit-identity-pinned modules must not reduce
over containers whose iteration order is not fixed.

Float addition is not associative: ``sum over a set`` yields a
different bit pattern depending on hash-seed-dependent iteration
order, which breaks the rtol-1e-9 legacy equivalence pin and the PR
6/7 digest pins without any *logical* bug.  Scope:
``contracts.PINNED_MODULES``.

  DY401  reduction (``sum``/``min``/``max``/``np.sum``/...) directly
         over a set expression (literal, ``set()``/``frozenset()``
         call, or a generator iterating one)
  DY402  for-loop over a set expression or ``dict.values()/.items()/
         .keys()`` whose body accumulates with an augmented assignment

``min``/``max`` over a set are order-sensitive through tie-breaking
(and NaN propagation); dict iteration is insertion-ordered in CPython
but the VALUES being accumulated then depend on insertion history —
sort the keys when the sum feeds pinned state, or suppress with a
reason stating why the insertion order is itself pinned.
"""

from __future__ import annotations

import ast
from typing import List

from tools.lint import Finding, Module
from tools.lint.astutil import ImportMap, dotted, is_set_expr

NAME = "float-order"

CODES = {
    "DY401": "reduction over a set in a bit-identity-pinned module",
    "DY402": "unordered iteration feeding accumulation in a pinned module",
}

_REDUCERS = frozenset({"sum", "min", "max", "prod"})
_NUMPY_REDUCERS = frozenset({
    "numpy.sum", "numpy.prod", "numpy.cumsum", "numpy.mean",
    "numpy.min", "numpy.max", "numpy.median", "numpy.std", "numpy.var",
})


def applies(relpath: str, contracts) -> bool:
    return relpath in contracts.PINNED_MODULES


def _reduces_set(call: ast.Call, imports: ImportMap) -> bool:
    for arg in call.args:
        if is_set_expr(arg, imports):
            return True
        if isinstance(arg, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            for gen in arg.generators:
                if is_set_expr(gen.iter, imports):
                    return True
    return False


def _dict_view(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr in ("values", "items", "keys")
        and not node.args
    )


def _accumulates(body: List[ast.stmt]) -> bool:
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.AugAssign) and isinstance(
                node.op, (ast.Add, ast.Sub, ast.Mult, ast.Div)
            ):
                return True
    return False


def run(module: Module, contracts) -> List[Finding]:
    imports = ImportMap(module.tree)
    out: List[Finding] = []

    def add(code: str, node: ast.AST, msg: str) -> None:
        out.append(Finding(
            code=code, path=module.path, line=node.lineno,
            col=node.col_offset, message=msg,
        ))

    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call):
            is_reducer = (
                isinstance(node.func, ast.Name)
                and node.func.id in _REDUCERS
                and not imports.is_module_alias(node.func.id)
            ) or dotted(node.func, imports) in _NUMPY_REDUCERS
            if is_reducer and _reduces_set(node, imports):
                add("DY401", node,
                    "reduction over a set: float-op order follows hash "
                    "iteration order, which is not pinned — sort first")
        elif isinstance(node, ast.For):
            if is_set_expr(node.iter, imports) and _accumulates(
                node.body
            ):
                add("DY402", node,
                    "accumulating over set iteration: float-op order "
                    "follows hash iteration order — iterate a sorted "
                    "sequence instead")
            elif _dict_view(node.iter) and _accumulates(node.body):
                add("DY402", node,
                    "accumulating over dict iteration: the float-op "
                    "order is the dict's insertion history — iterate "
                    "sorted(d) if this feeds pinned state, or suppress "
                    "with a reason the insertion order is itself "
                    "pinned")
    return out
