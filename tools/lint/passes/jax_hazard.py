"""DY3xx — jax hazards in jit-reachable functions.

The tick hot path stays one fused dispatch only while nothing inside it
forces a host sync or a trace-time Python decision on traced values.
This pass derives the module's jit-reachable function set (decorators,
``jax.jit(...)`` call sites, functions handed to ``jax.*`` transforms,
cross-module entries from ``contracts.JIT_REACHABLE``), closes it over
intra-module calls, and checks each reachable body.

Staticness is tracked conservatively per function: parameters are
traced unless named static (``static_argnames``, ``partial``-bound
config kwargs, contract hints); ``.shape``/``.ndim``/``.dtype``/
``.size``/``len()`` of anything is static; names assigned from
all-static expressions are static; unknown globals (module constants,
imported modules, enums) are static.  Hazards are only reported for
expressions involving a traced value, so shape math never trips the
pass.

  DY301  host sync: ``.item()``, or ``float()``/``int()``/``bool()``
         on a traced value
  DY302  host-numpy call (``np.asarray``/``np.array``/...) on a traced
         value (device transfer + trace break)
  DY303  Python branch (``if``/``while``/``assert``/ternary) on a
         traced value — decided at trace time, not per element; use
         ``jnp.where``/``lax.cond``
  DY304  retrace hazard: immediately-invoked ``jax.jit(...)(...)``
         (fresh cache entry per call), or a mutable default argument
         on a jit function (unhashable as a static)
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from tools.lint import Finding, Module
from tools.lint.astutil import ImportMap, dotted

NAME = "jax-hazard"

CODES = {
    "DY301": "host sync (.item()/float()/int()) in jit-reachable code",
    "DY302": "host-numpy call on a traced value in jit-reachable code",
    "DY303": "Python branch on a traced value in jit-reachable code",
    "DY304": "retrace hazard (per-call jit / unhashable static)",
}

_NUMPY_HOST_CALLS = frozenset({
    "asarray", "array", "asanyarray", "copy", "copyto", "save", "savez",
})

_STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size"})

#: Builtins that map static inputs to static outputs.
_STATIC_BUILTINS = frozenset({
    "len", "min", "max", "int", "float", "bool", "abs", "range",
    "tuple", "list", "sorted", "isinstance", "round",
})


def applies(relpath: str, contracts) -> bool:
    return relpath.endswith(".py")


def _jit_callee(node: ast.AST, imports: ImportMap) -> bool:
    d = dotted(node, imports)
    return d in ("jax.jit", "jax.pjit", "jax.experimental.pjit.pjit")


def _static_argnames(call: ast.Call) -> Set[str]:
    """static_argnames=("a", "b") / "a" keyword of a jit/partial call."""
    out: Set[str] = set()
    for k in call.keywords:
        if k.arg == "static_argnames":
            v = k.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                out.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List, ast.Set)):
                for e in v.elts:
                    if isinstance(e, ast.Constant) and isinstance(
                        e.value, str
                    ):
                        out.add(e.value)
    return out


class _Reach:
    """Worklist entry: function name -> static parameter names."""

    def __init__(self):
        self.static_params: Dict[str, Set[str]] = {}

    def add(self, name: str, statics: Set[str]) -> bool:
        cur = self.static_params.get(name)
        if cur is None:
            self.static_params[name] = set(statics)
            return True
        # Re-reaching with FEWER statics must widen the traced set.
        narrowed = cur & statics
        if narrowed != cur:
            self.static_params[name] = narrowed
            return True
        return False


def _collect_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    """All function defs (nested included), by name — last def wins,
    which matches runtime rebinding for the module-level case."""
    out: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            out[node.name] = node
    return out


def _seed_reachable(
    module: Module, imports: ImportMap, contracts,
    functions: Dict[str, ast.FunctionDef],
) -> Tuple[_Reach, List[Finding]]:
    reach = _Reach()
    findings: List[Finding] = []

    def fn_ref(node: ast.AST) -> Tuple[Optional[str], Set[str]]:
        """Resolve a callable expression to (local function name,
        partial-bound static names)."""
        if isinstance(node, ast.Name) and node.id in functions:
            return node.id, set()
        if isinstance(node, ast.Call) and dotted(
            node.func, imports
        ) == "functools.partial":
            statics = {k.arg for k in node.keywords if k.arg}
            statics |= _static_argnames(node)
            if node.args and isinstance(node.args[0], ast.Name):
                name = node.args[0].id
                if name in functions:
                    return name, statics
        return None, set()

    # Decorated definitions.
    for fn in functions.values():
        for dec in fn.decorator_list:
            statics: Set[str] = set()
            target = dec
            if isinstance(dec, ast.Call):
                d = dotted(dec.func, imports)
                if d == "functools.partial" and dec.args and _jit_callee(
                    dec.args[0], imports
                ):
                    statics = _static_argnames(dec)
                    reach.add(fn.name, statics)
                    continue
                target = dec.func
                statics = _static_argnames(dec)
            if _jit_callee(target, imports):
                reach.add(fn.name, statics)

    # jax.jit(...) call sites and functions handed to jax transforms.
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func, imports)
        if _jit_callee(node.func, imports):
            statics = _static_argnames(node)
            if node.args:
                name, bound = fn_ref(node.args[0])
                if name is not None:
                    reach.add(name, statics | bound)
            # Immediately-invoked jit: jax.jit(f)(x) builds a fresh
            # cache entry every execution.
        elif d is not None and d.startswith("jax.") and not d.startswith(
            ("jax.tree", "jax.tree_util")
        ):
            # Tracing transforms (vmap, grad, scan, pallas_call, ...)
            # make their function arguments jit-reachable.  jax.tree.*
            # is excluded: tree mapping is eager structural plumbing.
            for arg in list(node.args) + [k.value for k in node.keywords]:
                name, bound = fn_ref(arg)
                if name is not None:
                    reach.add(name, bound)

    # Immediately-invoked jit detection (DY304).
    for node in ast.walk(module.tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Call)
            and _jit_callee(node.func.func, imports)
        ):
            findings.append(Finding(
                code="DY304", path=module.path, line=node.lineno,
                col=node.col_offset,
                message="jax.jit(...)(...) jits and invokes in one "
                        "expression — every execution builds a fresh "
                        "traced callable; cache the jitted function",
            ))

    # Cross-module contract hints.
    for name, statics in contracts.JIT_REACHABLE.get(
        module.path, {}
    ).items():
        if name in functions:
            reach.add(name, set(statics))
    return reach, findings


# ------------------------- per-function analysis ---------------------- #


class _StaticNames:
    """Forward-pass approximation of which local names hold static
    (trace-time Python) values inside one function."""

    def __init__(
        self, fn: ast.FunctionDef, static_params: Set[str],
        imports: ImportMap,
        functions: Dict[str, ast.FunctionDef] = None,
        static_calls: frozenset = frozenset(),
    ):
        self.imports = imports
        self.functions = functions or {}
        self.static_calls = static_calls
        params = {
            a.arg for a in (
                fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
            )
        }
        if fn.args.vararg:
            params.add(fn.args.vararg.arg)
        if fn.args.kwarg:
            params.add(fn.args.kwarg.arg)
        self.traced: Set[str] = {
            p for p in params if p not in static_params and p != "self"
        }
        self.static: Set[str] = set(static_params)
        # Two fixpoint sweeps over straight-line assignments cover the
        # chains that occur in practice (N = x.shape[0]; b = min(b, N)).
        for _ in range(2):
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and len(node.targets) == 1:
                    t = node.targets[0]
                    if isinstance(t, ast.Name):
                        if self.is_static(node.value):
                            self.static.add(t.id)
                            self.traced.discard(t.id)
                        else:
                            self.traced.add(t.id)
                            self.static.discard(t.id)

    def is_static(self, node: ast.AST) -> bool:
        """Conservatively: does this expression provably hold a static
        (non-traced) value?"""
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            if node.id in self.traced:
                return False
            # static locals, module aliases, module-level constants,
            # builtins: all trace-time Python values.
            return True
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return True  # shapes/dtypes of traced arrays are static
            return self.is_static(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_static(node.value) and self.is_static(
                node.slice
            )
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return all(self.is_static(e) for e in node.elts)
        if isinstance(node, ast.BinOp):
            return self.is_static(node.left) and self.is_static(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_static(node.operand)
        if isinstance(node, ast.BoolOp):
            return all(self.is_static(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` are sentinel checks on the
            # Python structure, static regardless of x.
            if all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops
            ):
                return True
            # `"key" in pytree_dict` tests the (static) tree STRUCTURE,
            # not the traced leaves.
            if any(
                isinstance(op, (ast.In, ast.NotIn)) for op in node.ops
            ) and any(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in [node.left] + node.comparators
            ):
                return True
            return self.is_static(node.left) and all(
                self.is_static(c) for c in node.comparators
            )
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name):
                if node.func.id == "len":
                    return True  # len of a traced array is its static dim
                if node.func.id == "isinstance":
                    return True
                if node.func.id in _STATIC_BUILTINS:
                    return all(
                        self.is_static(a) for a in node.args
                    ) and all(
                        self.is_static(k.value) for k in node.keywords
                    )
                # A module-local helper fed only static values (shape
                # math like `_factored(p.shape, threshold)`) returns a
                # static value.
                if node.func.id in self.functions:
                    return all(
                        self.is_static(a) for a in node.args
                    ) and all(
                        self.is_static(k.value) for k in node.keywords
                    )
            # Contract-listed host-config reads (perf flags etc.).
            d = dotted(node.func, self.imports)
            if d is not None and d in self.static_calls:
                return True
            return False  # unknown call results are assumed traced
        if isinstance(node, ast.IfExp):
            return (
                self.is_static(node.test)
                and self.is_static(node.body)
                and self.is_static(node.orelse)
            )
        if isinstance(node, ast.Slice):
            return all(
                p is None or self.is_static(p)
                for p in (node.lower, node.upper, node.step)
            )
        if isinstance(node, (ast.JoinedStr, ast.FormattedValue)):
            return True  # message formatting, not numeric state
        return False


def _check_function(
    module: Module, fn: ast.FunctionDef, statics: Set[str],
    imports: ImportMap, functions: Dict[str, ast.FunctionDef],
    reach: _Reach, static_calls: frozenset,
) -> Tuple[List[Finding], List[Tuple[str, Set[str]]]]:
    names = _StaticNames(fn, statics, imports, functions, static_calls)
    out: List[Finding] = []
    newly: List[Tuple[str, Set[str]]] = []
    nested = {
        n.name for n in ast.walk(fn)
        if isinstance(n, ast.FunctionDef) and n is not fn
    }

    def add(code: str, node: ast.AST, msg: str) -> None:
        out.append(Finding(
            code=code, path=module.path, line=node.lineno,
            col=node.col_offset, message=msg,
        ))

    # DY304: mutable defaults on the jit function itself.
    for default in list(fn.args.defaults) + [
        d for d in fn.args.kw_defaults if d is not None
    ]:
        if isinstance(default, (ast.List, ast.Dict, ast.Set)):
            add("DY304", default,
                f"{fn.name} has a mutable default argument; as a jit "
                "static it is unhashable and forces a retrace")

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            # .item() host sync.
            if isinstance(f, ast.Attribute) and f.attr == "item":
                if not names.is_static(f.value):
                    add("DY301", node,
                        ".item() blocks on device transfer inside a "
                        "jit-reachable function")
            # float()/int()/bool() on traced values.
            elif isinstance(f, ast.Name) and f.id in (
                "float", "int", "bool"
            ):
                if node.args and not names.is_static(node.args[0]):
                    add("DY301", node,
                        f"{f.id}() on a traced value forces a host "
                        "sync inside a jit-reachable function")
            else:
                d = dotted(f, imports)
                if (
                    d is not None
                    and d.startswith("numpy.")
                    and d.rsplit(".", 1)[1] in _NUMPY_HOST_CALLS
                ):
                    if any(
                        not names.is_static(a) for a in node.args
                    ):
                        add("DY302", node,
                            f"`{d}` on a traced value transfers to "
                            "host inside a jit-reachable function; "
                            "use jnp")
                # Intra-module call propagation.
                if isinstance(f, ast.Name) and (
                    f.id in functions or f.id in nested
                ):
                    callee = functions.get(f.id)
                    if callee is not None:
                        cal_params = [
                            a.arg for a in callee.args.args
                            if a.arg != "self"
                        ]
                        stat: Set[str] = set()
                        for i, a in enumerate(node.args):
                            if i < len(cal_params) and names.is_static(a):
                                stat.add(cal_params[i])
                        for k in node.keywords:
                            if k.arg and names.is_static(k.value):
                                stat.add(k.arg)
                        newly.append((f.id, stat))
            # A function passed by name to ANY call inside a
            # jit-reachable body (tree_map of a local closure, a
            # higher-order helper) is itself jit-reachable, with every
            # parameter traced.
            for a in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(a, ast.Name) and a.id in functions:
                    newly.append((a.id, set()))
        elif isinstance(node, (ast.If, ast.While)):
            if not names.is_static(node.test):
                add("DY303", node,
                    "Python branch on a traced value is decided once "
                    "at trace time; use jnp.where / lax.cond")
        elif isinstance(node, ast.Assert):
            if not names.is_static(node.test):
                add("DY303", node,
                    "assert on a traced value forces a host sync (or "
                    "silently checks a tracer); assert static shapes "
                    "only")
        elif isinstance(node, ast.IfExp):
            if not names.is_static(node.test):
                add("DY303", node,
                    "ternary on a traced value is decided once at "
                    "trace time; use jnp.where")
    return out, newly


def run(module: Module, contracts) -> List[Finding]:
    imports = ImportMap(module.tree)
    functions = _collect_functions(module.tree)
    reach, findings = _seed_reachable(module, imports, contracts, functions)
    static_calls = frozenset(getattr(contracts, "STATIC_CALLS", ()))

    checked: Dict[str, Set[str]] = {}
    work = list(reach.static_params.items())
    while work:
        name, statics = work.pop()
        fn = functions.get(name)
        if fn is None:
            continue
        prev = checked.get(name)
        if prev is not None and prev <= set(statics):
            continue  # already checked with an equal-or-wider traced set
        checked[name] = set(statics)
        fn_findings, calls = _check_function(
            module, fn, set(statics), imports, functions, reach,
            static_calls,
        )
        findings.extend(fn_findings)
        for callee, stat in calls:
            if reach.add(callee, stat):
                work.append((callee, reach.static_params[callee]))

    # Deduplicate (a function re-checked with a narrower static set can
    # re-emit the same findings).
    seen = set()
    unique: List[Finding] = []
    for f in findings:
        k = (f.code, f.path, f.line, f.col, f.message)
        if k not in seen:
            seen.add(k)
            unique.append(f)
    return unique
