"""DY6xx — pin impact: which functions feed the bit-identity pins, as
a committed artifact.

``contracts.PINS`` declares each pin's call-graph roots (the functions
the pinned tests drive).  This pass computes the forward reachability
closure of every pin over the interprocedural graph (``graph.py``) and
checks three things:

  DY601  the committed ``tools/lint/pin_map.json`` does not match the
         computed closures — regenerate with
         ``python tools/lint/runner.py --write-pin-map`` (CI fails on
         a stale map, so "which functions feed which pins" is a
         reviewed diff, not tribal knowledge)
  DY602  a module reached by a pin closure is missing from
         ``contracts.PINNED_MODULES`` (the float-order pass and the
         reviewers' attention skip it)
  DY603  policy/plugin code writes engine-owned state through its
         ``PolicyContext`` views (``self.ctx.*``) — policies may
         observe the engine, never steer it behind the engine's back
  DY604  a declared pin root does not resolve to a known function

DY601/DY602/DY604 anchor to the declaration they contradict in
``src/repro/core/contracts.py``; DY603 anchors to the offending write.
"""

from __future__ import annotations

import ast
import json
import os
from typing import Dict, List, Optional, Tuple

from tools.lint import Finding
from tools.lint.graph import UNKNOWN, ClassInfo, Program

NAME = "pin-impact"

CODES = {
    "DY601": "committed pin-impact map (pin_map.json) is stale",
    "DY602": "pin-reachable module missing from PINNED_MODULES",
    "DY603": "policy writes engine-owned state through a PolicyContext "
             "view",
    "DY604": "bit-identity pin root does not resolve",
}

PIN_MAP_VERSION = 1

#: Container methods that mutate their receiver in place.
_MUTATORS = frozenset({
    "append", "extend", "insert", "remove", "pop", "popitem", "clear",
    "update", "setdefault", "add", "discard", "sort", "reverse",
    "fill", "put", "itemset",
})

_CONTRACTS_PATH = "src/repro/core/contracts.py"


def applies(relpath: str, contracts) -> bool:  # per-module API: unused
    return False


# ------------------------------------------------------------------- #
# The map
# ------------------------------------------------------------------- #

def compute_pin_map(program: Program, contracts) -> dict:
    """The committed artifact: pin -> roots, reachable functions,
    reachable modules, and whether the closure is over-approximate
    (contains an unresolved callee)."""
    pins: Dict[str, dict] = {}
    for name in sorted(contracts.PINS):
        spec = contracts.PINS[name]
        roots = [r for r in spec["roots"]
                 if program.resolve_root(r) is not None]
        closure = program.closure(roots)
        funcs = sorted(n for n in closure if n != UNKNOWN)
        pins[name] = {
            "test": spec["test"],
            "roots": sorted(spec["roots"]),
            "functions": funcs,
            "modules": sorted({n.split("::")[0] for n in funcs}),
            "over_approximate": UNKNOWN in closure,
        }
    return {"version": PIN_MAP_VERSION, "pins": pins}


def dump_pin_map(pin_map: dict) -> str:
    return json.dumps(pin_map, indent=2, sort_keys=True) + "\n"


def load_pin_map(path: str) -> Optional[dict]:
    try:
        with open(path, encoding="utf-8") as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None


# ------------------------------------------------------------------- #
# Findings
# ------------------------------------------------------------------- #

def _contracts_line(program: Program, needle: str) -> int:
    """1-based line in contracts.py containing ``needle`` (anchors the
    finding to the declaration it contradicts)."""
    try:
        lines = program.cache.get(_CONTRACTS_PATH).lines
    except (OSError, SyntaxError):
        return 1
    for i, line in enumerate(lines, 1):
        if needle in line:
            return i
    return 1


def _check_pins(program: Program, contracts,
                out: List[Finding]) -> dict:
    for name in sorted(contracts.PINS):
        for root in contracts.PINS[name]["roots"]:
            if program.resolve_root(root) is None:
                out.append(Finding(
                    code="DY604", path=_CONTRACTS_PATH,
                    line=_contracts_line(program, root.split("::")[-1]),
                    col=0,
                    message=f"pin {name!r} root {root!r} does not "
                            f"resolve to a known function — fix the "
                            f"PINS entry or the renamed symbol",
                ))
    pin_map = compute_pin_map(program, contracts)
    committed = load_pin_map(
        os.path.join(program.root, contracts.PIN_MAP_PATH)
    )
    if committed != pin_map:
        out.append(Finding(
            code="DY601", path=_CONTRACTS_PATH,
            line=_contracts_line(program, "PIN_MAP_PATH"),
            col=0,
            message=f"{contracts.PIN_MAP_PATH} is stale "
                    f"{'(missing/unreadable) ' if committed is None else ''}"
                    f"— regenerate with `python tools/lint/runner.py "
                    f"--write-pin-map` and commit the diff",
        ))
    pinned = set(contracts.PINNED_MODULES)
    missing: Dict[str, List[str]] = {}
    for name, spec in pin_map["pins"].items():
        for mod in spec["modules"]:
            if mod not in pinned:
                missing.setdefault(mod, []).append(name)
    for mod in sorted(missing):
        out.append(Finding(
            code="DY602", path=_CONTRACTS_PATH,
            line=_contracts_line(program, "PINNED_MODULES"),
            col=0,
            message=f"{mod} is reachable from pin(s) "
                    f"{', '.join(missing[mod])} but missing from "
                    f"PINNED_MODULES — acknowledge it (and accept the "
                    f"float-order pass there) or cut the edge",
        ))
    return pin_map


# ------------------------------------------------------------------- #
# Ownership: policies must not write through ctx views
# ------------------------------------------------------------------- #

def _ctx_rooted(node: ast.expr) -> Tuple[bool, int]:
    """Does this access chain pass through a ``ctx`` attribute (or a
    bare ``ctx`` name)?  Returns (rooted, steps beyond the ctx link) —
    ``self.ctx`` itself is 0 steps (rebinding the view handle, legal);
    ``self.ctx.outstanding()[p]`` is > 0 (a write THROUGH the view)."""
    steps = 0
    cur = node
    while True:
        if isinstance(cur, ast.Attribute):
            if cur.attr == "ctx":
                return True, steps
            steps += 1
            cur = cur.value
        elif isinstance(cur, ast.Subscript):
            steps += 1
            cur = cur.value
        elif isinstance(cur, ast.Call):
            steps += 1
            cur = cur.func
        elif isinstance(cur, ast.Name):
            return (cur.id == "ctx"), steps
        else:
            return False, steps


def _check_ownership(program: Program, contracts,
                     out: List[Finding]) -> None:
    classes: List[ClassInfo] = []
    base = program.policy_base
    if base is not None:
        classes.append(base)
        classes.extend(program.subclasses(base))
    for ci in program.policy_classes:
        if ci not in classes:
            classes.append(ci)

    def flag(path: str, node: ast.AST, cls: str, what: str) -> None:
        out.append(Finding(
            code="DY603", path=path, line=node.lineno,
            col=node.col_offset,
            message=f"{cls}: {what} — PolicyContext views are "
                    f"engine-owned observations; a policy influences "
                    f"routing only through its return values",
        ))

    for ci in classes:
        for fi in ci.methods.values():
            for node in ast.walk(fi.node):
                if isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)):
                    targets = (node.targets
                               if isinstance(node, ast.Assign)
                               else [node.target])
                    for t in targets:
                        rooted, steps = _ctx_rooted(t)
                        if rooted and steps > 0:
                            flag(ci.path, node, ci.name,
                                 f"assigns through ctx view "
                                 f"`{ast.unparse(t)}`")
                elif isinstance(node, ast.Delete):
                    for t in node.targets:
                        rooted, steps = _ctx_rooted(t)
                        if rooted and steps > 0:
                            flag(ci.path, node, ci.name,
                                 f"deletes through ctx view "
                                 f"`{ast.unparse(t)}`")
                elif isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Attribute
                ) and node.func.attr in _MUTATORS:
                    rooted, steps = _ctx_rooted(node.func.value)
                    if rooted and steps >= 0:
                        flag(ci.path, node, ci.name,
                             f"mutates a ctx view in place "
                             f"(`{ast.unparse(node.func)}`)")


def run_program(program: Program, contracts,
                extra_paths=()) -> List[Finding]:
    """Whole-program entry point (see ``passes.PROGRAM_PASSES``).
    ``extra_paths`` is accepted for interface parity with the units
    pass; pin impact is defined by the graph scope alone."""
    out: List[Finding] = []
    _check_pins(program, contracts, out)
    _check_ownership(program, contracts, out)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return out
