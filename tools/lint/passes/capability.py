"""DY2xx — capability contract: a registered policy's declared flags
must match what its method bodies actually do.

The engine's fast paths (closed-form drain, closed-form 'none', batched
planning) dispatch on ``RedistributionPolicy`` capability FLAGS, not on
code — a plugin that declares ``drain_safe=True`` while mutating state
outside ``route``/``propose`` silently corrupts the rtol-1e-9
equivalence pin the first time the drain licenses an early heap exit.
This pass cross-checks every ``@register_policy`` class AST against its
declared flags (defaults from ``contracts.CAPABILITY_FLAGS``).

  DY201  ``ctx.rng`` consulted without ``stochastic=True``
  DY202  ``self.*`` mutated outside ``route``/``propose`` (or private
         helpers reachable only from them) while ``drain_safe=True``
  DY203  ``link_mask`` read (or ``set_link_mask`` overridden) without
         ``uses_link=True``
  DY204  ``never_redistributes=True`` but ``route``/``propose``/
         ``assign`` is not provably producer-preserving
  DY205  ``stochastic=True`` declared but ``ctx.rng`` never consulted

Limits (by design — this is a single-file AST pass): flags inherited
from intermediate base classes other than ``RedistributionPolicy`` are
not followed, and mutation through aliasing (``s = self; s.x = 1``) is
not tracked.  Suppress with a one-line reason where the analysis is
too conservative.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.lint import Finding, Module
from tools.lint.astutil import (
    ImportMap,
    assign_targets,
    self_attribute,
)

NAME = "capability"

CODES = {
    "DY201": "ctx.rng use requires stochastic=True",
    "DY202": "self mutation outside route/propose with drain_safe=True",
    "DY203": "link_mask read requires uses_link=True",
    "DY204": "never_redistributes=True not provably producer-preserving",
    "DY205": "stochastic=True declared but ctx.rng never consulted",
}

#: Method calls that mutate their receiver.
_MUTATING_METHODS = frozenset({
    "append", "extend", "insert", "remove", "pop", "clear", "add",
    "discard", "update", "setdefault", "popitem", "sort", "reverse",
})


def applies(relpath: str, contracts) -> bool:
    return relpath.endswith(".py")


def _is_policy_class(cls: ast.ClassDef, contracts) -> bool:
    for dec in cls.decorator_list:
        node = dec.func if isinstance(dec, ast.Call) else dec
        name = node.attr if isinstance(node, ast.Attribute) else (
            node.id if isinstance(node, ast.Name) else None
        )
        if name == contracts.POLICY_DECORATOR:
            return True
    return False


def _declared_flags(cls: ast.ClassDef, contracts) -> Dict[str, bool]:
    flags = dict(contracts.CAPABILITY_FLAGS)
    for stmt in cls.body:
        for t in assign_targets(stmt):
            if isinstance(t, ast.Name) and t.id in flags:
                value = getattr(stmt, "value", None)
                if isinstance(value, ast.Constant) and isinstance(
                    value.value, bool
                ):
                    flags[t.id] = value.value
    return flags


def _is_ctx_rng(node: ast.AST, contracts) -> bool:
    """ctx.rng or self.ctx.rng."""
    if not (
        isinstance(node, ast.Attribute)
        and node.attr == contracts.RNG_ATTRIBUTE
    ):
        return False
    base = node.value
    if isinstance(base, ast.Name) and base.id == "ctx":
        return True
    return self_attribute(base) == "ctx"


def _mutations(fn: ast.FunctionDef) -> List[ast.AST]:
    """Nodes in ``fn`` that mutate ``self`` state: assignments to
    ``self.x`` / ``self.x[...]``, and mutating method calls on
    ``self.x``."""
    out: List[ast.AST] = []
    for node in ast.walk(fn):
        for t in assign_targets(node) if isinstance(
            node, (ast.Assign, ast.AugAssign, ast.AnnAssign)
        ) else ():
            base = t
            while isinstance(base, ast.Subscript):
                base = base.value
            if self_attribute(base) is not None:
                out.append(t)
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in _MUTATING_METHODS
        ):
            recv = node.func.value
            while isinstance(recv, ast.Subscript):
                recv = recv.value
            if self_attribute(recv) is not None:
                out.append(node)
    return out


def _self_calls(fn: ast.FunctionDef) -> Set[str]:
    """Names of ``self.<method>()`` calls made inside ``fn``."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = self_attribute(node.func)
            if name is not None:
                out.add(name)
    return out


def _mutation_allowed_methods(
    methods: Dict[str, ast.FunctionDef], contracts
) -> Set[str]:
    """The fixpoint of ``contracts.MUTATION_SAFE_METHODS`` plus private
    helpers every in-class caller of which is already allowed (a helper
    called only from ``propose`` mutates only while routing)."""
    calls = {name: _self_calls(fn) for name, fn in methods.items()}
    allowed = {m for m in contracts.MUTATION_SAFE_METHODS if m in methods}
    changed = True
    while changed:
        changed = False
        for name in methods:
            if name in allowed or not name.startswith("_"):
                continue
            if name.startswith("__"):
                continue
            callers = {c for c, callees in calls.items() if name in callees}
            if callers and callers <= allowed:
                allowed.add(name)
                changed = True
    return allowed


# ------------------------- never_redistributes ------------------------ #


def _returns(fn: ast.FunctionDef) -> List[ast.Return]:
    return [n for n in ast.walk(fn) if isinstance(n, ast.Return)]


def _always_returns_none(fn: ast.FunctionDef) -> bool:
    for r in _returns(fn):
        if r.value is not None and not (
            isinstance(r.value, ast.Constant) and r.value.value is None
        ):
            return False
    return True


def _propose_all_on_producer(fn: ast.FunctionDef) -> bool:
    """True when every return is None or a counts vector whose only
    written cell is ``counts[producer] = k`` — the one shape of propose
    the closed-form 'none' path can accept."""
    args = [a.arg for a in fn.args.args]
    # propose(self, producer, k, backlog, unit)
    if len(args) < 3:
        return False
    producer, k = args[1], args[2]
    counts_names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in assign_targets(node):
                if (
                    isinstance(t, ast.Subscript)
                    and isinstance(t.value, ast.Name)
                ):
                    idx, val = t.slice, node.value
                    if (
                        isinstance(idx, ast.Name) and idx.id == producer
                        and isinstance(val, ast.Name) and val.id == k
                    ):
                        counts_names.add(t.value.id)
                    else:
                        return False  # writes some other cell
        elif isinstance(node, ast.AugAssign):
            t = node.target
            base = t
            while isinstance(base, ast.Subscript):
                base = base.value
            if isinstance(base, ast.Name) and base.id in counts_names:
                return False
    for r in _returns(fn):
        if r.value is None or (
            isinstance(r.value, ast.Constant) and r.value.value is None
        ):
            continue
        if not (
            isinstance(r.value, ast.Name) and r.value.id in counts_names
        ):
            return False
    return True


def _assign_producer_preserving(
    fn: ast.FunctionDef, imports: ImportMap
) -> bool:
    """True when every return expression's name leaves are the
    ``producers`` parameter (plus module aliases for dtype spellings) —
    ``return producers.copy()`` / ``return np.asarray(producers,
    np.int64).copy()``."""
    args = [a.arg for a in fn.args.args]
    # assign(self, costs, producers, n)
    if len(args) < 3:
        return False
    producers = args[2]
    for r in _returns(fn):
        if r.value is None:
            return False
        names = {
            n.id for n in ast.walk(r.value) if isinstance(n, ast.Name)
        }
        extra = {
            n for n in names
            if n != producers and not imports.is_module_alias(n)
        }
        if producers not in names or extra:
            return False
    return True


def run(module: Module, contracts) -> List[Finding]:
    imports = ImportMap(module.tree)
    out: List[Finding] = []

    def add(code: str, node: ast.AST, msg: str) -> None:
        out.append(Finding(
            code=code, path=module.path, line=node.lineno,
            col=node.col_offset, message=msg,
        ))

    for cls in ast.walk(module.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        if not _is_policy_class(cls, contracts):
            continue
        flags = _declared_flags(cls, contracts)
        methods = {
            f.name: f for f in cls.body if isinstance(f, ast.FunctionDef)
        }

        # DY201 / DY205: injected-RNG use vs the stochastic flag.
        rng_nodes = [
            n for fn in methods.values() for n in ast.walk(fn)
            if _is_ctx_rng(n, contracts)
        ]
        if rng_nodes and not flags["stochastic"]:
            for n in rng_nodes:
                add("DY201", n,
                    f"{cls.name} consults ctx.rng but declares "
                    "stochastic=False; the engine's same-seed "
                    "reproducibility pins assume non-stochastic "
                    "policies never draw")
        if flags["stochastic"] and not rng_nodes:
            add("DY205", cls,
                f"{cls.name} declares stochastic=True but never "
                "consults ctx.rng; drop the flag or draw from the "
                "injected stream")

        # DY203: link-mask reads vs uses_link.
        if not flags["uses_link"]:
            mask_nodes = [
                n for fn in methods.values() for n in ast.walk(fn)
                if self_attribute(n) == contracts.LINK_MASK_ATTRIBUTE
            ]
            if "set_link_mask" in methods:
                mask_nodes.append(methods["set_link_mask"])
            for n in mask_nodes:
                add("DY203", n,
                    f"{cls.name} touches link_mask but declares "
                    "uses_link=False; the engine only creates and "
                    "ticks link instances for uses_link policies, so "
                    "the mask would be permanently all-False")

        # DY202: self mutation outside the drain-safe methods.
        if flags["drain_safe"]:
            allowed = _mutation_allowed_methods(methods, contracts)
            for name, fn in methods.items():
                if name in allowed:
                    continue
                for node in _mutations(fn):
                    add("DY202", node,
                        f"{cls.name}.{name} mutates self state; "
                        "drain_safe=True promises state changes only "
                        "inside route/propose — clear drain_safe or "
                        "move the mutation")

        # DY204: never_redistributes must be provable.
        if flags["never_redistributes"]:
            route = methods.get("route")
            if route is not None and not _always_returns_none(route):
                add("DY204", route,
                    f"{cls.name}.route can return a destination vector "
                    "but never_redistributes=True licenses the "
                    "closed-form 'none' fast path")
            propose = methods.get("propose")
            if propose is not None and not _propose_all_on_producer(
                propose
            ):
                add("DY204", propose,
                    f"{cls.name}.propose is not provably "
                    "all-k-on-producer but never_redistributes=True "
                    "licenses the closed-form 'none' fast path")
            assign = methods.get("assign")
            if assign is not None and not _assign_producer_preserving(
                assign, imports
            ):
                add("DY204", assign,
                    f"{cls.name}.assign does not provably return the "
                    "producers vector unchanged but "
                    "never_redistributes=True licenses the closed-form "
                    "'none' fast path")
    return out
