"""DY1xx — determinism: sim-path code must be a pure function of its
seeds and configuration.

Scope: ``contracts.DETERMINISM_SCOPE`` (src/repro/{sim,core,serving,
data}).  Virtual time comes from the event heap and randomness from
seeds threaded through configs; one wall-clock read or global-RNG draw
silently corrupts the rtol-1e-9 legacy equivalence pin in a way no
runtime assertion can localize.

  DY101  global numpy RNG sampler (``np.random.choice`` on the module
         singleton — unseeded process-global state)
  DY102  argless generator (``default_rng()`` / ``RandomState()``, or
         a bare ``default_rng`` reference passed as a factory) — a
         fresh OS-entropy stream per call
  DY103  stdlib ``random`` module use (global Mersenne Twister)
  DY104  wall-clock read (``time.time``, ``perf_counter``,
         ``datetime.now``, ...) — virtual time only
  DY105  iteration over ``os.environ`` — environment-order-dependent
         control flow
"""

from __future__ import annotations

import ast
from typing import List

from tools.lint import Finding, Module
from tools.lint.astutil import ImportMap, dotted

NAME = "determinism"

CODES = {
    "DY101": "global numpy RNG sampler in sim-path code",
    "DY102": "argless RNG generator (fresh OS-entropy stream)",
    "DY103": "stdlib `random` module use in sim-path code",
    "DY104": "wall-clock read in sim-path code",
    "DY105": "iteration over os.environ in sim-path code",
}

#: Samplers/state mutators on the numpy.random module singleton.  The
#: seeded-generator constructors (default_rng(seed), Generator,
#: SeedSequence, PCG64, ...) are deliberately absent.
_SAMPLERS = frozenset({
    "seed", "get_state", "set_state", "random", "random_sample", "ranf",
    "sample", "rand", "randn", "randint", "random_integers", "bytes",
    "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "standard_cauchy", "standard_exponential",
    "standard_gamma", "standard_t", "poisson", "exponential", "beta",
    "binomial", "chisquare", "dirichlet", "f", "gamma", "geometric",
    "gumbel", "hypergeometric", "laplace", "logistic", "lognormal",
    "logseries", "multinomial", "multivariate_normal",
    "negative_binomial", "noncentral_chisquare", "noncentral_f",
    "pareto", "power", "rayleigh", "triangular", "vonmises", "wald",
    "weibull", "zipf",
})

_GENERATORS = frozenset({
    "numpy.random.default_rng",
    "numpy.random.RandomState",
})

_WALL_CLOCKS = frozenset({
    "time.time", "time.time_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})


def applies(relpath: str, contracts) -> bool:
    return relpath.endswith(".py") and any(
        relpath.startswith(p) for p in contracts.DETERMINISM_SCOPE
    )


def _is_environ(node: ast.AST, imports: ImportMap) -> bool:
    """os.environ, or os.environ.keys()/values()/items()."""
    if (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr in ("keys", "values", "items")
    ):
        node = node.func.value
    return dotted(node, imports) == "os.environ"


def run(module: Module, contracts) -> List[Finding]:
    imports = ImportMap(module.tree)
    out: List[Finding] = []

    def add(code: str, node: ast.AST, msg: str) -> None:
        out.append(Finding(
            code=code, path=module.path, line=node.lineno,
            col=node.col_offset, message=msg,
        ))

    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call):
            d = dotted(node.func, imports)
            if d is not None:
                if (
                    d.startswith("numpy.random.")
                    and d.rsplit(".", 1)[1] in _SAMPLERS
                ):
                    add("DY101", node,
                        f"`{d}` draws from the process-global numpy RNG; "
                        "use the injected seeded generator "
                        "(PolicyContext.rng / default_rng(seed))")
                elif d in _GENERATORS and not node.args and not any(
                    k.arg in ("seed",) and not _is_none(k.value)
                    for k in node.keywords
                ):
                    add("DY102", node,
                        f"argless `{d}()` creates a fresh OS-entropy "
                        "stream; thread an explicit seed through")
                elif d.startswith("random.") or d == "random":
                    add("DY103", node,
                        f"`{d}` uses the global Mersenne Twister; use a "
                        "seeded np.random.default_rng instead")
                elif d in _WALL_CLOCKS:
                    add("DY104", node,
                        f"`{d}()` reads the wall clock; sim-path code "
                        "runs on virtual (heap) time only")
            # Bare generator reference passed as a factory argument
            # (e.g. `field(default_factory=np.random.default_rng)`):
            # called later with no seed — same hazard as DY102.
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(arg, (ast.Name, ast.Attribute)):
                    ad = dotted(arg, imports)
                    if ad in _GENERATORS:
                        add("DY102", arg,
                            f"bare `{ad}` passed as a factory is an "
                            "argless-generator call in disguise; wrap "
                            "it with an explicit seed")
        elif isinstance(node, (ast.For, ast.comprehension)):
            it = node.iter
            if _is_environ(it, imports):
                add("DY105", it,
                    "iterating os.environ makes control flow depend on "
                    "environment contents/order")
    return out


def _is_none(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and node.value is None
