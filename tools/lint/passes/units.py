"""DY5xx — units/dimensions: the economics and latency formulas must
not mix units.

A silent unit bug — seconds added to bytes, a ``*_gb`` budget compared
to a ``*_bytes`` occupancy, worker-seconds (the autoscale billing
currency) folded into plain wall seconds — corrupts a BENCH record or
an admission threshold without failing a single test.  This pass runs
over the WHOLE program (``contracts.UNITS_SCOPE``): units are seeded
from the naming vocabulary in ``contracts.UNIT_SUFFIXES`` /
``UNIT_NAME_PATTERNS`` (``wall_s``, ``kv_bytes``, ``deficit_rows``)
and propagated through assignments, arithmetic, comparisons, calls
(arguments matched to the callee's parameter names through the
interprocedural graph) and return values (a function named ``*_s`` or
returning unit-named expressions types its call sites).

  DY501  cross-dimension arithmetic (seconds + bytes)
  DY502  cross-dimension comparison (incl. ``min``/``max`` arguments)
  DY503  unit-typed value silently coerced (assignment or call
         argument whose declared unit disagrees in dimension)
  DY504  same-dimension scale mixing (``*_gb`` vs ``*_bytes``,
         ``*_ms`` vs ``*_s``)

The lattice is deliberately conservative: a violation is reported only
when BOTH sides carry a known unit.  Numeric literals are
unit-compatible with everything (``x_s * 2`` is fine); multiplication
and division produce derived dimensions this pass does not track
(``bytes / s`` is a rate, not an error) except that dividing two
values of the SAME unit yields a dimensionless ratio.  Dividing or
multiplying by a literal that lands exactly on another scale in the
vocabulary PERFORMS the conversion (``kv_bytes / 2**30`` is gb;
``kv_bytes / 1e9`` is a mislabel and stays flagged), while any other
literal leaves the scale unknown — same dimension, no scale verdict.
"""

from __future__ import annotations

import ast
import math
import os
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from tools.lint import Finding, Module
from tools.lint.astutil import ImportMap, dotted
from tools.lint.graph import FunctionInfo, Program

NAME = "units"

CODES = {
    "DY501": "cross-dimension arithmetic (e.g. seconds + bytes)",
    "DY502": "cross-dimension comparison",
    "DY503": "unit-typed value silently coerced across dimensions",
    "DY504": "same-dimension scale mixing (e.g. *_gb vs *_bytes)",
}

#: Unit lattice: ``None`` = unknown, ``ANY`` = numeric literal
#: (compatible with everything), ``(dimension, scale)`` otherwise.
ANY = ("any", 0.0)
RATIO = ("ratio", 1.0)

#: Builtins/numpy reducers that preserve their first argument's unit.
_PRESERVING = frozenset({"abs", "float", "round", "sum"})
_NUMPY_PRESERVING = re.compile(
    r"\.(sum|mean|median|std|min|max|amin|amax|nanmin|nanmax|cumsum|"
    r"percentile|quantile|clip|abs|maximum|minimum)$"
)


def applies(relpath: str, contracts) -> bool:  # per-module API: unused
    return False


def _compiled_patterns(contracts):
    pats = getattr(contracts, "_DYFLOW_UNIT_PATS", None)
    if pats is None:
        pats = [
            (re.compile(rx), tuple(unit))
            for rx, unit in contracts.UNIT_NAME_PATTERNS
        ]
        contracts._DYFLOW_UNIT_PATS = pats
    return pats


def unit_of_name(name: str, contracts) -> Optional[Tuple[str, float]]:
    """Seed unit from a name per the contracts vocabulary (whole-name
    patterns first, then the ``_<suffix>`` rule).  A bare suffix with
    no stem (a variable literally named ``s``) declares nothing."""
    low = name.lower()
    for rx, unit in _compiled_patterns(contracts):
        if rx.search(low):
            return unit
    if "_" not in low:
        return None
    suffix = low.rsplit("_", 1)[1]
    u = contracts.UNIT_SUFFIXES.get(suffix)
    return tuple(u) if u else None


def _known(u: Optional[Tuple[str, float]]) -> bool:
    return u is not None and u != ANY


def _const_value(e: ast.expr) -> Optional[float]:
    """Fold a literal numeric expression (``1e9``, ``2 ** 30``,
    ``1 << 30``, ``1024 * 1024``) to its value, else None."""
    if isinstance(e, ast.Constant):
        if isinstance(e.value, bool) or not isinstance(
            e.value, (int, float)
        ):
            return None
        return float(e.value)
    if isinstance(e, ast.UnaryOp) and isinstance(e.op, ast.USub):
        v = _const_value(e.operand)
        return -v if v is not None else None
    if isinstance(e, ast.BinOp):
        a = _const_value(e.left)
        b = _const_value(e.right)
        if a is None or b is None:
            return None
        try:
            if isinstance(e.op, ast.Mult):
                return a * b
            if isinstance(e.op, ast.Div):
                return a / b
            if isinstance(e.op, ast.Pow):
                return float(a ** b)
            if isinstance(e.op, ast.LShift):
                return float(int(a) << int(b))
            if isinstance(e.op, ast.Add):
                return a + b
            if isinstance(e.op, ast.Sub):
                return a - b
        except (OverflowError, ZeroDivisionError, ValueError):
            return None
    if isinstance(e, ast.Call) and isinstance(e.func, ast.Name) \
            and e.func.id in ("float", "int") and len(e.args) == 1 \
            and not e.keywords:
        return _const_value(e.args[0])
    return None


def _dim_scales(contracts) -> Dict[str, Set[float]]:
    """Every scale the vocabulary names, per dimension — the targets a
    literal multiply/divide may legally convert between."""
    scales = getattr(contracts, "_DYFLOW_DIM_SCALES", None)
    if scales is None:
        scales = {}
        for dim, scale in contracts.UNIT_SUFFIXES.values():
            scales.setdefault(dim, set()).add(float(scale))
        for _, (dim, scale) in contracts.UNIT_NAME_PATTERNS:
            scales.setdefault(dim, set()).add(float(scale))
        contracts._DYFLOW_DIM_SCALES = scales
    return scales


class _Scope:
    """One lexical scope: the module's import map, its top-level
    function table (for intra-module call resolution), and the local
    name -> unit environment."""

    __slots__ = ("mi", "imports", "localfuncs", "env")

    def __init__(self, mi, imports, localfuncs, env=None):
        self.mi = mi                  # ModuleInfo or None (benchmarks)
        self.imports = imports
        self.localfuncs = localfuncs  # name -> ast def node
        self.env: Dict[str, Tuple[str, float]] = env or {}

    def child(self) -> "_Scope":
        return _Scope(self.mi, self.imports, self.localfuncs,
                      dict(self.env))


class _UnitChecker:
    def __init__(self, program: Program, contracts):
        self.prog = program
        self.c = contracts
        self.findings: List[Finding] = []
        self._emitted: Set[Tuple[str, str, int, int]] = set()
        self._ret_cache: Dict[int, Optional[Tuple[str, float]]] = {}
        self._ret_stack: Set[int] = set()
        self._path = ""

    # --------------------------------------------------------------- #
    # Findings
    # --------------------------------------------------------------- #

    def _add(self, code: str, node: ast.AST, msg: str) -> None:
        key = (code, self._path, node.lineno, node.col_offset)
        if key in self._emitted:
            return
        self._emitted.add(key)
        self.findings.append(Finding(
            code=code, path=self._path, line=node.lineno,
            col=node.col_offset, message=msg,
        ))

    @staticmethod
    def _fmt(u: Tuple[str, Optional[float]]) -> str:
        dim, scale = u
        if scale is None:
            return f"{dim}(rescaled)"
        return dim if scale == 1.0 else f"{dim}(x{scale:g})"

    def _flag_pair(
        self, node: ast.AST, a, b, what: str,
        dim_code: str, scale_code: str = "DY504",
    ) -> None:
        """Emit the dimension- or scale-mixing finding for a known
        conflicting pair.  A ``None`` scale (value rescaled by an
        arbitrary literal) carries no scale verdict."""
        if a[0] != b[0]:
            self._add(dim_code, node,
                      f"{what} mixes dimensions: {self._fmt(a)} vs "
                      f"{self._fmt(b)}")
        elif a[1] is not None and b[1] is not None and a[1] != b[1]:
            self._add(scale_code, node,
                      f"{what} mixes scales of {a[0]}: {self._fmt(a)} "
                      f"vs {self._fmt(b)} — convert explicitly")

    # --------------------------------------------------------------- #
    # Expression units (also recurses into every sub-expression)
    # --------------------------------------------------------------- #

    def expr(self, e: ast.expr, s: _Scope) -> Optional[Tuple[str, float]]:
        if isinstance(e, ast.Constant):
            if isinstance(e.value, bool) or not isinstance(
                e.value, (int, float)
            ):
                return None
            return ANY
        if isinstance(e, ast.Name):
            if e.id in s.env:
                return s.env[e.id]
            return unit_of_name(e.id, self.c)
        if isinstance(e, ast.Attribute):
            self.expr(e.value, s)
            return unit_of_name(e.attr, self.c)
        if isinstance(e, ast.BinOp):
            return self._binop(e, s)
        if isinstance(e, ast.Compare):
            self._compare(e, s)
            return None
        if isinstance(e, ast.Call):
            return self._call(e, s)
        if isinstance(e, ast.UnaryOp):
            return self.expr(e.operand, s)
        if isinstance(e, ast.IfExp):
            self.expr(e.test, s)
            a = self.expr(e.body, s)
            b = self.expr(e.orelse, s)
            if a == b:
                return a
            if _known(a) and b == ANY:
                return a
            if _known(b) and a == ANY:
                return b
            return None
        if isinstance(e, ast.BoolOp):
            units = [self.expr(v, s) for v in e.values]
            known = [u for u in units if _known(u)]
            return known[0] if known else None
        if isinstance(e, ast.Subscript):
            # d["wall_s"] declares; a_s[i] inherits the container's unit
            self.expr(e.slice, s)
            if isinstance(e.slice, ast.Constant) and isinstance(
                e.slice.value, str
            ):
                container = self.expr(e.value, s)
                key_u = unit_of_name(e.slice.value, self.c)
                return key_u if key_u is not None else container
            return self.expr(e.value, s)
        if isinstance(e, (ast.List, ast.Tuple, ast.Set)):
            units = [self.expr(v, s) for v in e.elts]
            known = [u for u in units if _known(u)]
            if known and all(u == known[0] for u in known):
                return known[0]
            return None
        if isinstance(e, ast.Dict):
            for k, v in zip(e.keys, e.values):
                vu = self.expr(v, s)
                if k is None:
                    continue
                self.expr(k, s)
                if isinstance(k, ast.Constant) and isinstance(
                    k.value, str
                ):
                    ku = unit_of_name(k.value, self.c)
                    if _known(ku) and _known(vu):
                        self._flag_pair(
                            v, ku, vu, f"dict value for key "
                            f"{k.value!r}", "DY503")
            return None
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            sub = s.child()
            for gen in e.generators:
                self.expr(gen.iter, s)
                for cond in gen.ifs:
                    self.expr(cond, sub)
            return self.expr(e.elt, sub)
        if isinstance(e, ast.Starred):
            return self.expr(e.value, s)
        # fallback: visit children so nested BinOp/Compare still checked
        for child in ast.iter_child_nodes(e):
            if isinstance(child, ast.expr):
                self.expr(child, s)
        return None

    def _binop(self, e: ast.BinOp, s: _Scope):
        a = self.expr(e.left, s)
        b = self.expr(e.right, s)
        if isinstance(e.op, (ast.Add, ast.Sub)):
            if _known(a) and _known(b) and a != b:
                self._flag_pair(
                    e, a, b,
                    "addition" if isinstance(e.op, ast.Add)
                    else "subtraction", "DY501")
                return None
            if _known(a):
                return a if b in (ANY, a, None) and b is not None else None
            if _known(b):
                return b if a == ANY else None
            return ANY if a == ANY and b == ANY else None
        if isinstance(e.op, ast.Mult):
            if a == ANY and _known(b):
                return self._rescaled(b, e.left, invert=True)
            if b == ANY and _known(a):
                return self._rescaled(a, e.right, invert=True)
            if _known(a) and b == RATIO:
                return a
            if _known(b) and a == RATIO:
                return b
            return ANY if a == ANY and b == ANY else None
        if isinstance(e.op, (ast.Div, ast.FloorDiv)):
            if _known(a) and _known(b) and a[0] == b[0]:
                return RATIO if a[1] == b[1] else None
            if _known(a) and b == ANY:
                return self._rescaled(a, e.right, invert=False)
            if _known(a) and b == RATIO:
                return a
            return ANY if a == ANY and b == ANY else None
        return None

    def _rescaled(self, u, literal: ast.expr, invert: bool):
        """Unit after multiplying (``invert=True``) or dividing a
        ``u``-typed value by a numeric literal.  A literal landing
        exactly on another vocabulary scale PERFORMS the conversion
        (``bytes / 2**30`` -> gb); anything else keeps the dimension
        but forgets the scale."""
        dim, scale = u
        c = _const_value(literal)
        if c in (None, 0.0) or scale is None:
            return (dim, None)
        new = scale / c if invert else scale * c
        near = None
        for known_scale in _dim_scales(self.c).get(dim, ()):
            if math.isclose(new, known_scale, rel_tol=1e-9):
                return (dim, known_scale)
            if math.isclose(new, known_scale, rel_tol=0.1):
                near = known_scale
        if near is not None:
            # NEAR a vocabulary scale but not on it: the decimal-vs-
            # binary confusion class (``/ 1e9`` where gb means 2**30).
            # Keep the computed scale so the use site reports the
            # mismatch instead of silently forgetting it.
            return (dim, new)
        return (dim, None)

    def _compare(self, e: ast.Compare, s: _Scope) -> None:
        units = [self.expr(e.left, s)]
        units.extend(self.expr(cmp, s) for cmp in e.comparators)
        known = [(u, n) for u, n in zip(units, [e.left] + e.comparators)
                 if _known(u)]
        for (a, _), (b, node) in zip(known, known[1:]):
            if a != b:
                self._flag_pair(e, a, b, "comparison", "DY502")

    def _call(self, e: ast.Call, s: _Scope):
        fname = None
        if isinstance(e.func, ast.Name):
            fname = e.func.id
        elif isinstance(e.func, ast.Attribute):
            fname = e.func.attr
            self.expr(e.func.value, s)
        arg_units = [self.expr(a, s) for a in e.args]
        kw_units = {kw.arg: self.expr(kw.value, s) for kw in e.keywords}
        # min/max compare their arguments
        if isinstance(e.func, ast.Name) and fname in ("min", "max"):
            known = [u for u in arg_units if _known(u)]
            for a, b in zip(known, known[1:]):
                if a != b:
                    self._flag_pair(e, a, b, f"{fname}() arguments",
                                    "DY502")
            return known[0] if known and all(
                u == known[0] for u in known
            ) else None
        if isinstance(e.func, ast.Name) and fname in _PRESERVING:
            return arg_units[0] if arg_units else None
        d = dotted(e.func, s.imports)
        if d and _NUMPY_PRESERVING.search(d):
            return arg_units[0] if arg_units else None
        # program function: match args to parameter names, use returns
        target = self._resolve(e.func, s)
        if target is not None:
            self._check_args(e, target, arg_units, kw_units, s)
            return self._return_unit(target)
        # unresolved: the callee NAME may still declare the unit
        return unit_of_name(fname, self.c) if fname else None

    # --------------------------------------------------------------- #
    # Interprocedural pieces
    # --------------------------------------------------------------- #

    def _resolve(self, func: ast.expr, s: _Scope):
        """Callee ast def node + its module scope, or None.  Only
        direct function calls (local name or imported dotted path) are
        matched — method dispatch falls back to name seeding."""
        if isinstance(func, ast.Name):
            node = s.localfuncs.get(func.id)
            if node is not None:
                return (node, s)
        d = dotted(func, s.imports)
        if d is not None:
            sym = self.prog.lookup_dotted(d)
            if isinstance(sym, FunctionInfo):
                mi = self.prog.modules.get(sym.path)
                if mi is not None:
                    tscope = _Scope(
                        mi, mi.imports,
                        {n: f.node for n, f in mi.functions.items()},
                    )
                    return (sym.node, tscope)
        return None

    def _check_args(self, e, target, arg_units, kw_units, s) -> None:
        node, _tscope = target
        a = node.args
        params = [p.arg for p in a.posonlyargs + a.args]
        if params and params[0] in ("self", "cls"):
            params = params[1:]
        for i, (arg, u) in enumerate(zip(e.args, arg_units)):
            if i >= len(params) or not _known(u):
                continue
            pu = unit_of_name(params[i], self.c)
            if _known(pu) and pu != u:
                self._flag_pair(
                    arg, u, pu,
                    f"argument for parameter {params[i]!r} of "
                    f"{node.name}()", "DY503")
        kw_names = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
        for kw in e.keywords:
            u = kw_units.get(kw.arg)
            if kw.arg is None or kw.arg not in kw_names or not _known(u):
                continue
            pu = unit_of_name(kw.arg, self.c)
            if _known(pu) and pu != u:
                self._flag_pair(
                    kw.value, u, pu,
                    f"argument for parameter {kw.arg!r} of "
                    f"{node.name}()", "DY503")

    def _return_unit(self, target) -> Optional[Tuple[str, float]]:
        """A function's result unit: its own name's suffix, else the
        consistent unit of its return expressions."""
        node, tscope = target
        named = unit_of_name(node.name, self.c)
        if named is not None:
            return named
        key = id(node)
        if key in self._ret_cache:
            return self._ret_cache[key]
        if key in self._ret_stack:        # recursion: give up soundly
            return None
        self._ret_stack.add(key)
        # returns are typed in a throwaway env (param names only);
        # findings inside the body come from its own module walk, so
        # silence emission while peeking.
        saved, self.findings = self.findings, []
        units = set()
        sub = _Scope(tscope.mi, tscope.imports, tscope.localfuncs)
        for st in ast.walk(node):
            if isinstance(st, ast.Return) and st.value is not None:
                units.add(self.expr(st.value, sub))
        self.findings = saved
        self._ret_stack.discard(key)
        known = {u for u in units if _known(u)}
        out = known.pop() if len(known) == 1 and units <= known | {
            ANY
        } else None
        self._ret_cache[key] = out
        return out

    # --------------------------------------------------------------- #
    # Statements
    # --------------------------------------------------------------- #

    def _target_unit(self, t: ast.expr, s: _Scope):
        """Declared unit of an assignment target (None if undeclared)."""
        if isinstance(t, ast.Name):
            return unit_of_name(t.id, self.c)
        if isinstance(t, ast.Attribute):
            return unit_of_name(t.attr, self.c)
        if isinstance(t, ast.Subscript) and isinstance(
            t.slice, ast.Constant
        ) and isinstance(t.slice.value, str):
            return unit_of_name(t.slice.value, self.c)
        return None

    def _bind(self, t: ast.expr, value_u, s: _Scope,
              where: ast.AST) -> None:
        tu = self._target_unit(t, s)
        if _known(tu) and _known(value_u) and tu != value_u:
            self._flag_pair(where, value_u, tu,
                            f"assignment to {ast.unparse(t)!r}", "DY503")
        if isinstance(t, ast.Name):
            u = tu if tu is not None else value_u
            if u is not None:
                s.env[t.id] = u
            else:
                s.env.pop(t.id, None)

    def stmt(self, st: ast.stmt, s: _Scope) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in st.decorator_list:
                self.expr(dec, s)
            a = st.args
            sub = s.child()
            for p in a.posonlyargs + a.args + a.kwonlyargs:
                pu = unit_of_name(p.arg, self.c)
                if pu is not None:
                    sub.env[p.arg] = pu
                else:
                    sub.env.pop(p.arg, None)
            for dflt in list(a.defaults) + [
                d for d in a.kw_defaults if d is not None
            ]:
                self.expr(dflt, s)
            for b in st.body:
                self.stmt(b, sub)
            return
        if isinstance(st, ast.ClassDef):
            for dec in st.decorator_list:
                self.expr(dec, s)
            sub = _Scope(s.mi, s.imports, s.localfuncs)
            for b in st.body:
                self.stmt(b, sub)
            return
        if isinstance(st, ast.Assign):
            vu = self.expr(st.value, s)
            for t in st.targets:
                if isinstance(t, (ast.Tuple, ast.List)) and isinstance(
                    st.value, (ast.Tuple, ast.List)
                ) and len(t.elts) == len(st.value.elts):
                    for te, ve in zip(t.elts, st.value.elts):
                        self._bind(te, self.expr(ve, s), s, te)
                else:
                    self._bind(t, vu, s, st)
                    if isinstance(t, (ast.Subscript, ast.Attribute)):
                        self.expr(t.value, s)
            return
        if isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self._bind(st.target, self.expr(st.value, s), s, st)
            return
        if isinstance(st, ast.AugAssign):
            vu = self.expr(st.value, s)
            tu = self._target_unit(st.target, s)
            if isinstance(st.target, ast.Name) and tu is None:
                tu = s.env.get(st.target.id)
            if isinstance(st.op, (ast.Add, ast.Sub)) and _known(tu) \
                    and _known(vu) and tu != vu:
                self._flag_pair(st, tu, vu, "augmented assignment",
                                "DY501")
            return
        # generic: visit child expressions and statements
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.expr):
                self.expr(child, s)
            elif isinstance(child, ast.stmt):
                self.stmt(child, s)
            else:
                # e.g. withitem / excepthandler wrappers
                for sub in ast.iter_child_nodes(child):
                    if isinstance(sub, ast.expr):
                        self.expr(sub, s)
                    elif isinstance(sub, ast.stmt):
                        self.stmt(sub, s)

    # --------------------------------------------------------------- #
    # Module / tree drivers
    # --------------------------------------------------------------- #

    def check_module(self, relpath: str, module: Module) -> None:
        self._path = relpath
        mi = self.prog.modules.get(relpath)
        imports = mi.imports if mi else ImportMap(module.tree)
        localfuncs = {
            st.name: st for st in module.tree.body
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        scope = _Scope(mi, imports, localfuncs)
        for st in module.tree.body:
            self.stmt(st, scope)


def _scope_files(program: Program, contracts) -> List[str]:
    out: List[str] = []
    for prefix in contracts.UNITS_SCOPE:
        base = os.path.join(program.root, prefix)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(
                d for d in dirnames
                if d != "__pycache__" and not d.startswith(".")
            )
            for name in sorted(filenames):
                if name.endswith(".py"):
                    full = os.path.join(dirpath, name)
                    out.append(
                        os.path.relpath(full, program.root).replace(
                            os.sep, "/"
                        )
                    )
    return out


def run_program(
    program: Program, contracts,
    extra_paths: Sequence[str] = (),
) -> List[Finding]:
    """Whole-program entry point (see ``passes.PROGRAM_PASSES``).

    ``extra_paths`` are repo-relative files OUTSIDE ``UNITS_SCOPE`` the
    caller explicitly asked to lint (fixtures, one-off scripts) — the
    runner passes files named on the command line, never directory
    sweeps."""
    checker = _UnitChecker(program, contracts)
    seen: Set[str] = set()
    for rel in list(_scope_files(program, contracts)) + list(extra_paths):
        if rel in seen:
            continue
        seen.add(rel)
        try:
            module = program.cache.get(rel)
        except (OSError, SyntaxError):
            continue                # per-module pass reports DY001
        checker.check_module(rel, module)
    checker.findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return checker.findings
