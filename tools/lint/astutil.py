"""Shared AST helpers for the dyslint passes.

The central primitive is import-alias resolution: a pass never matches
on the literal text ``np.random.choice`` — it resolves the root name
through the module's imports, so ``import numpy as xp`` followed by
``xp.random.choice(...)`` is caught and a local variable that happens
to be called ``np`` is not.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional


class ImportMap:
    """Maps local names to the dotted import path they are bound to.

    ``import numpy as np``          -> np: numpy
    ``import numpy.random as npr``  -> npr: numpy.random
    ``from numpy import random``    -> random: numpy.random
    ``from time import perf_counter as pc`` -> pc: time.perf_counter
    """

    def __init__(self, tree: ast.AST):
        self.names: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.names[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom):
                if node.level or not node.module:
                    continue  # relative imports stay unresolved
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.names[a.asname or a.name] = (
                        f"{node.module}.{a.name}"
                    )

    def is_module_alias(self, name: str) -> bool:
        return name in self.names


def dotted(node: ast.AST, imports: ImportMap) -> Optional[str]:
    """Resolve a Name/Attribute chain to a dotted path rooted at an
    import, e.g. ``np.random.choice`` -> ``numpy.random.choice``.
    Returns None when the root is not an imported name (a local
    variable, a call result, ...)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = imports.names.get(node.id)
    if root is None:
        return None
    parts.append(root)
    return ".".join(reversed(parts))


def call_name(node: ast.Call, imports: ImportMap) -> Optional[str]:
    """``dotted`` of a call's callee (None for non-import callees)."""
    return dotted(node.func, imports)


def is_set_expr(node: ast.AST, imports: ImportMap) -> bool:
    """Syntactically-recognizable unordered container: a set literal, a
    set comprehension, or a ``set(...)``/``frozenset(...)`` call
    (builtin, not shadowed by an import)."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return (
            node.func.id in ("set", "frozenset")
            and not imports.is_module_alias(node.func.id)
        )
    return False


def walk_functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    """Every (sync) function definition in the tree, nested included."""
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            yield node


def self_attribute(node: ast.AST) -> Optional[str]:
    """``self.x`` -> "x"; anything else -> None."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def assign_targets(stmt: ast.stmt) -> list:
    """Flattened assignment target expressions of an Assign/AugAssign/
    AnnAssign statement (tuple targets unpacked)."""
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    else:
        return []
    flat = []
    stack = targets
    while stack:
        t = stack.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            stack.extend(t.elts)
        else:
            flat.append(t)
    return flat
