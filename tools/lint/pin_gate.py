"""Diff-aware pin-impact gate: run the bit-identity pin tests a diff
can actually affect.

CI calls this after the lint job has verified ``pin_map.json`` is
fresh: the committed map names which modules feed which pins, so a PR
that touches pin-covered code gets an EXPLICIT run of exactly the
digest/equivalence tests it endangers — and a PR that doesn't gets a
fast no-op instead of "trust the full suite caught it".

Usage::

    python tools/lint/pin_gate.py --base origin/main      # diff vs ref
    python tools/lint/pin_gate.py path1.py path2.py ...   # explicit
    python tools/lint/pin_gate.py --list --base origin/main  # plan only

Exit status: 0 when no pin is affected or every affected pin's test
passes; the pytest exit status otherwise.  Changes to the analyzer
itself (``tools/lint/``), to the contract layer, or to a pin's test
file conservatively affect EVERY pin.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Sequence

_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)
)))

_CONTRACTS = "src/repro/core/contracts.py"

#: Prefixes whose changes invalidate the map/analysis itself.
_GLOBAL_PREFIXES = ("tools/lint/", _CONTRACTS)


def changed_files(base: str) -> List[str]:
    out = subprocess.run(
        ["git", "diff", "--name-only", f"{base}...HEAD"],
        cwd=_ROOT, capture_output=True, text=True, check=True,
    ).stdout
    return [line.strip() for line in out.splitlines() if line.strip()]


def affected_pins(
    files: Sequence[str], pin_map: dict
) -> Dict[str, List[str]]:
    """pin name -> the changed files that put it at risk."""
    out: Dict[str, List[str]] = {}
    for f in files:
        rel = f.replace(os.sep, "/")
        if rel.startswith(_GLOBAL_PREFIXES):
            for pin in pin_map["pins"]:
                out.setdefault(pin, []).append(rel)
            continue
        for pin, spec in pin_map["pins"].items():
            if rel in spec["modules"] or rel == spec["test"]:
                out.setdefault(pin, []).append(rel)
    return out


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="pin-gate", description=__doc__)
    ap.add_argument("files", nargs="*",
                    help="changed files (default: git diff vs --base)")
    ap.add_argument("--base", default="origin/main",
                    help="ref to diff against when no files are given")
    ap.add_argument("--map", default=os.path.join(
        _ROOT, "tools", "lint", "pin_map.json"))
    ap.add_argument("--list", action="store_true",
                    help="print the plan without running pytest")
    args = ap.parse_args(argv)

    with open(args.map, encoding="utf-8") as fh:
        pin_map = json.load(fh)
    files = args.files or changed_files(args.base)
    affected = affected_pins(files, pin_map)
    if not affected:
        print(f"pin gate: {len(files)} changed file(s) touch no "
              f"pin-covered module — nothing to re-run")
        return 0
    tests = sorted({
        pin_map["pins"][pin]["test"] for pin in affected
    })
    for pin in sorted(affected):
        print(f"pin gate: {pin} affected via "
              f"{', '.join(sorted(set(affected[pin]))[:4])}"
              f"{' ...' if len(set(affected[pin])) > 4 else ''}")
    print(f"pin gate: running {' '.join(tests)}")
    if args.list:
        return 0
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p
    )
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", *tests],
        cwd=_ROOT, env=env,
    )
    return proc.returncode


if __name__ == "__main__":
    sys.exit(main())
