"""Doc-link checker: verify that file/module references in the user-facing
docs resolve against the working tree.

Scans README.md and docs/ARCHITECTURE.md for backtick-quoted tokens that
look like repository paths (``src/repro/sim/engine.py``, ``docs/``,
``benchmarks/run.py``) or dotted repro modules (``repro.core.admission``)
and fails with a non-zero exit listing every reference that does not
exist.  Wired into ``make verify`` and ``benchmarks/run.py --check-docs``
so the docs cannot silently rot as the tree moves.
"""

from __future__ import annotations

import os
import re
import sys
from typing import List, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", os.path.join("docs", "ARCHITECTURE.md")]

_CODE_SPAN = re.compile(r"`([^`\n]+)`")
# A token is path-like if it contains a slash or a known file suffix.
_PATHISH = re.compile(r"^[\w./-]+$")
_SUFFIXES = (".py", ".md", ".txt", ".json", ".toml", ".cfg")
_MODULE = re.compile(r"^repro(\.\w+)+$")


def _candidate_kind(token: str) -> str:
    """'path' | 'module' | '' (not checkable)."""
    if _MODULE.match(token):
        return "module"
    if not _PATHISH.match(token):
        return ""
    if "/" in token or token.endswith(_SUFFIXES):
        # Exclude obvious non-paths: flags, versions, bare commands.
        if token.startswith("-") or token.replace(".", "").isdigit():
            return ""
        return "path"
    return ""


def _exists(token: str, kind: str) -> bool:
    if kind == "module":
        rel = os.path.join("src", *token.split("."))
        return (
            os.path.isdir(os.path.join(ROOT, rel))
            or os.path.isfile(os.path.join(ROOT, rel + ".py"))
        )
    p = os.path.join(ROOT, token.rstrip("/"))
    return os.path.exists(p)


def check(doc_paths: List[str] = DOCS) -> Tuple[int, List[str]]:
    """Returns (num_checked, failures)."""
    checked = 0
    failures: List[str] = []
    for doc in doc_paths:
        full = os.path.join(ROOT, doc)
        if not os.path.isfile(full):
            failures.append(f"{doc}: document missing")
            continue
        with open(full, encoding="utf-8") as f:
            text = f.read()
        for ln, line in enumerate(text.splitlines(), 1):
            for token in _CODE_SPAN.findall(line):
                token = token.strip()
                # Commands: check the file argument of `python <path>`.
                m = re.match(r"^(?:PYTHONPATH=\S+ )?python ([\w./-]+\.py)",
                             token)
                if m:
                    token = m.group(1)
                kind = _candidate_kind(token)
                if not kind:
                    continue
                checked += 1
                if not _exists(token, kind):
                    failures.append(f"{doc}:{ln}: unresolved reference "
                                    f"`{token}`")
    return checked, failures


def main() -> int:
    checked, failures = check()
    if failures:
        print(f"doc-link check FAILED ({len(failures)} unresolved, "
              f"{checked} checked):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"doc-link check OK ({checked} references resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
