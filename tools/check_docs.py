"""Doc-link checker: verify that file/module references in the user-facing
docs resolve against the working tree, and that fenced command snippets
actually parse.

Scans README.md and docs/ARCHITECTURE.md for backtick-quoted tokens that
look like repository paths (``src/repro/sim/engine.py``, ``docs/``,
``benchmarks/run.py``) or dotted repro modules (``repro.core.admission``)
and fails with a non-zero exit listing every reference that does not
exist.  Fenced ``bash``/``sh``/``console`` blocks get a second pass: each
command line must shlex-parse, ``python <file>`` arguments must exist,
and ``make <target>`` targets must be defined in the Makefile.  Wired
into ``make verify`` and ``benchmarks/run.py --check-docs`` so the docs
cannot silently rot as the tree moves.
"""

from __future__ import annotations

import os
import re
import shlex
import sys
from typing import List, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", os.path.join("docs", "ARCHITECTURE.md")]

_CODE_SPAN = re.compile(r"`([^`\n]+)`")
# A token is path-like if it contains a slash or a known file suffix.
_PATHISH = re.compile(r"^[\w./-]+$")
_SUFFIXES = (".py", ".md", ".txt", ".json", ".toml", ".cfg")
_MODULE = re.compile(r"^repro(\.\w+)+$")


def _candidate_kind(token: str) -> str:
    """'path' | 'module' | '' (not checkable)."""
    if _MODULE.match(token):
        return "module"
    if not _PATHISH.match(token):
        return ""
    if "/" in token or token.endswith(_SUFFIXES):
        # Exclude obvious non-paths: flags, versions, bare commands.
        if token.startswith("-") or token.replace(".", "").isdigit():
            return ""
        return "path"
    return ""


def _exists(token: str, kind: str) -> bool:
    if kind == "module":
        rel = os.path.join("src", *token.split("."))
        return (
            os.path.isdir(os.path.join(ROOT, rel))
            or os.path.isfile(os.path.join(ROOT, rel + ".py"))
        )
    p = os.path.join(ROOT, token.rstrip("/"))
    return os.path.exists(p)


def check(doc_paths: List[str] = DOCS) -> Tuple[int, List[str]]:
    """Returns (num_checked, failures)."""
    checked = 0
    failures: List[str] = []
    for doc in doc_paths:
        full = os.path.join(ROOT, doc)
        if not os.path.isfile(full):
            failures.append(f"{doc}: document missing")
            continue
        with open(full, encoding="utf-8") as f:
            text = f.read()
        for ln, line in enumerate(text.splitlines(), 1):
            for token in _CODE_SPAN.findall(line):
                token = token.strip()
                # Commands: check the file argument of `python <path>`.
                m = re.match(r"^(?:PYTHONPATH=\S+ )?python ([\w./-]+\.py)",
                             token)
                if m:
                    token = m.group(1)
                kind = _candidate_kind(token)
                if not kind:
                    continue
                checked += 1
                if not _exists(token, kind):
                    failures.append(f"{doc}:{ln}: unresolved reference "
                                    f"`{token}`")
    return checked, failures


_FENCE = re.compile(r"^```(\w*)\s*$")
_SHELL_LANGS = {"bash", "sh", "shell", "console"}
_ENV_ASSIGN = re.compile(r"^[A-Za-z_]\w*=\S*$")
_MAKE_TARGET = re.compile(r"^([\w][\w.-]*)\s*:(?!=)", re.MULTILINE)


def _makefile_targets() -> set:
    path = os.path.join(ROOT, "Makefile")
    if not os.path.isfile(path):
        return set()
    with open(path, encoding="utf-8") as f:
        targets = set(_MAKE_TARGET.findall(f.read()))
    targets.discard(".PHONY")
    return targets


def _check_command(tokens: List[str]) -> str:
    """'' if the command looks runnable against this tree, else why not."""
    # Skip env-var assignment prefixes (PYTHONPATH=src python ...).
    i = 0
    while i < len(tokens) and _ENV_ASSIGN.match(tokens[i]):
        i += 1
    if i >= len(tokens):
        return ""
    cmd, rest = tokens[i], tokens[i + 1:]
    if cmd.startswith("python"):
        for a in rest:
            if a in ("-m", "-c"):  # module invocations are covered by
                return ""          # the module-reference pass; -c has
                                   # no file argument to resolve
            if a.startswith("-"):
                continue
            if not os.path.isfile(os.path.join(ROOT, a)):
                return f"script `{a}` does not exist"
            return ""
    elif cmd == "make":
        targets = _makefile_targets()
        for a in rest:
            if not a.startswith("-") and "=" not in a and a not in targets:
                return f"make target `{a}` not defined in Makefile"
    return ""


def check_snippets(doc_paths: List[str] = DOCS) -> Tuple[int, List[str]]:
    """Verify fenced shell snippets: every command line must shlex-parse,
    `python <file>` scripts must exist, `make <target>` targets must be
    defined.  Returns (num_checked, failures)."""
    checked = 0
    failures: List[str] = []
    for doc in doc_paths:
        full = os.path.join(ROOT, doc)
        if not os.path.isfile(full):
            continue  # reported by check()
        with open(full, encoding="utf-8") as f:
            lines = f.read().splitlines()
        lang = None
        pending = ""       # backslash-continued command being assembled
        pending_ln = 0
        for ln, line in enumerate(lines, 1):
            m = _FENCE.match(line.strip())
            if m:
                lang = None if lang is not None else (m.group(1) or "text")
                pending = ""
                continue
            if lang not in _SHELL_LANGS:
                continue
            cmd = line.strip()
            if cmd.startswith("$ "):
                cmd = cmd[2:]
            elif lang == "console" and not pending:
                # Console blocks interleave commands ('$ '-prefixed) with
                # program output — output lines are not commands.
                continue
            if pending:
                cmd = pending + " " + cmd
                ln = pending_ln
                pending = ""
            if not cmd or cmd.startswith("#"):
                continue
            if cmd.endswith("\\"):
                pending, pending_ln = cmd[:-1].rstrip(), ln
                continue
            checked += 1
            try:
                tokens = shlex.split(cmd)
            except ValueError as e:
                failures.append(f"{doc}:{ln}: snippet does not parse "
                                f"({e}): {cmd!r}")
                continue
            # Compound commands: validate each segment between shell
            # operators (shlex keeps `&&`/`|`/`;` as plain tokens).
            segment: List[str] = []
            for tok in tokens + ["&&"]:
                if tok in ("&&", "||", "|", ";"):
                    if segment:
                        why = _check_command(segment)
                        if why:
                            failures.append(f"{doc}:{ln}: {why}: {cmd!r}")
                    segment = []
                else:
                    segment.append(tok)
    return checked, failures


def main() -> int:
    checked, failures = check()
    snip_checked, snip_failures = check_snippets()
    failures += snip_failures
    if failures:
        print(f"doc check FAILED ({len(failures)} problems; {checked} "
              f"references + {snip_checked} snippet lines checked):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"doc check OK ({checked} references resolve, "
          f"{snip_checked} snippet lines parse)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
