"""Serving example: continuous batching with DySkew request scheduling vs
round-robin under a skewed request mix (some requests generate 10x more
tokens — the serving analogue of heavy UDF rows).

Run:  PYTHONPATH=src python examples/serve_dyskew.py
"""

import numpy as np

from repro.serving.engine import Request, ServeConfig, ServingEngine

rng = np.random.default_rng(7)
requests = [
    Request(
        rid=i,
        prompt_len=int(rng.integers(64, 512)),
        # every 6th request is a long generation (skewed decode cost)
        max_new_tokens=int(rng.integers(400, 600)) if i % 6 == 0
        else int(rng.integers(20, 80)),
        arrival=float(i) * 0.015,
    )
    for i in range(96)
]

for sched in ("round_robin", "dyskew"):
    res = ServingEngine(ServeConfig(num_replicas=4, scheduler=sched)).run(
        [Request(**r.__dict__) for r in requests]  # fresh copies
    )
    print(f"{sched:12s} mean={res['mean_latency']:.2f}s "
          f"p99={res['p99_latency']:.2f}s migrations={res['migrations']} "
          f"migrated={res['migrated_gb']:.2f}GB")
