"""Quickstart: the DySkew adaptive link in 40 lines.

Creates 4 sibling link instances, feeds a skewed stream of work items, and
watches the state machines detect the skew and redistribute.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveLink, AdaptiveLinkConfig, DySkewConfig, Policy

link = AdaptiveLink(AdaptiveLinkConfig(
    dyskew=DySkewConfig(policy=Policy.LATE, n_strikes=3, theta=0.5),
    num_instances=4,
))
state = link.init_state()

print("tick | states (0=INIT 1=DECIDING 2=DRAIN 3=DIST 5=DIST_TERM) | makespan")
for tick in range(8):
    # 32 items, all arriving at producer 0 (severe partition skew).
    costs = jnp.ones(32) * 0.1
    sizes = jnp.full(32, 1e3)
    producer = jnp.zeros(32, jnp.int32)
    state, plan = link.step(state, costs, sizes, producer)
    loads = np.zeros(4)
    np.add.at(loads, np.asarray(plan.dest), np.asarray(costs))
    print(f"{tick:4d} | {np.asarray(state['state'])} | {loads.max():.2f} "
          f"(balanced would be {float(costs.sum())/4:.2f})")

print("\nThe LATE policy processed locally for 3 strikes, drained, then "
      "committed to distributed mode — makespan drops 4x.")
