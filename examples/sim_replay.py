"""Replay a skewed UDF query under all three strategies (paper Fig. 1-4
mechanics, small scale).

Run:  PYTHONPATH=src python examples/sim_replay.py
"""

from repro.sim.engine import ClusterConfig, Simulator
from repro.sim.replay import default_strategies, scan_arrival_gap
from repro.sim.workload import QueryProfile, generate_query

cluster = ClusterConfig(num_nodes=8)
profile = QueryProfile(
    name="demo", n_rows=12000, mean_row_cost=2e-3,
    cost_sigma=2.0,            # heavy-tailed UDF cost (the hard case)
    partition_alpha=0.4, hot_fraction=0.05,
)
batches = generate_query(profile, cluster.num_workers, seed=0)
gap = scan_arrival_gap(profile, cluster)

print(f"query: {profile.n_rows} rows, partition+cost skew, "
      f"{cluster.num_workers} interpreters on {cluster.num_nodes} nodes\n")
for name, st in default_strategies().items():
    r = Simulator(cluster, st, seed=0).run_query(batches, arrival_gap=gap)
    print(f"{name:10s} latency={r.latency:7.3f}s utilization={r.utilization:.2f} "
          f"rows_moved={r.rows_redistributed}")
