"""End-to-end training driver: ~100M-param MoE with DySkew adaptive
dispatch, a few hundred steps on CPU.

The MoE is granite-moe family (32 experts, top-8) scaled to ~100M params;
DySkew's per-EP-shard state machines manage expert capacity live during
training. Compares against the static-capacity baseline at the end.

Run:  PYTHONPATH=src python examples/train_moe_dyskew.py --steps 200
"""

import argparse
import dataclasses

from repro.config.base import ArchConfig, MoEConfig
from repro.data.pipeline import DataConfig
from repro.optim.optimizers import OptimizerConfig
from repro.train.loop import LoopConfig, train


def make_cfg(adaptive: bool) -> ArchConfig:
    # ~100M params: 8 layers, d=512, 32 experts × ff 512 top-8.
    return ArchConfig(
        name="moe-100m", family="moe", num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=8192,
        rope_style="full", norm="rmsnorm", mlp_act="swiglu",
        moe=MoEConfig(num_experts=32, top_k=8, expert_ff=512,
                      capacity_factor=1.0, adaptive=adaptive),
        optimizer="adamw", dtype="float32", remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    for mode in ("dyskew", "static"):
        cfg = make_cfg(adaptive=(mode == "dyskew"))
        n = sum(1 for _ in [0])  # placeholder
        data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=1)
        opt = OptimizerConfig(name="adamw", lr=1e-3,
                              warmup_steps=20, total_steps=args.steps)
        print(f"\n=== {mode} dispatch ===")
        out = train(cfg, data, opt, LoopConfig(
            steps=args.steps, log_every=max(args.steps // 10, 1)),
            on_metrics=lambda s, m: print(
                f"  step {s:4d} loss={m['loss']:.4f} "
                f"dropped={m.get('moe_dropped_frac', 0):.4f} "
                f"imbalance={m.get('moe_shard_imbalance', 0):.2f}"))
        h = out["history"]
        print(f"{mode}: loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}, "
              f"final dropped={h[-1].get('moe_dropped_frac', 0):.4f}")


if __name__ == "__main__":
    main()
