"""Fig. 4 — TPCx-BB (4 nodes): UDF queries under legacy static round-robin
vs DySkew.

Paper claims reproduced: Q10 +43 % and Q19 +36 % (the skewed
sentiment-analysis UDF queries); all other queries within ±5 %.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.sim.engine import ClusterConfig, Simulator
from repro.sim.replay import (
    dyskew_strategy,
    improvement,
    legacy_strategy,
)
from repro.sim.workload import generate_query, tpcxbb_suite

Row = Tuple[str, float, str]


def run(quick: bool = False) -> List[Row]:
    cluster = ClusterConfig(num_nodes=4)
    suite = tpcxbb_suite()
    if quick:
        suite = [p for p in suite if p.name in ("q05", "q10", "q19", "q22")]
    rows: List[Row] = []
    big_gain, small_diff = [], []
    for i, prof in enumerate(suite):
        batches = generate_query(prof, cluster.num_workers, seed=100 + i)
        rr = Simulator(cluster, legacy_strategy(prof), seed=i).run_query(batches)
        dk = Simulator(cluster, dyskew_strategy(prof), seed=i).run_query(batches)
        impr = improvement(rr.latency, dk.latency)
        rows.append((
            f"fig4_tpcxbb_{prof.name}",
            dk.latency * 1e6,
            f"improvement={impr:+.3f};legacy_us={rr.latency*1e6:.0f}",
        ))
        (big_gain if prof.name in ("q10", "q19") else small_diff).append(impr)
    rows.append((
        "fig4_summary",
        0.0,
        f"q10_q19_improvements={[f'{x:+.2f}' for x in big_gain]};"
        f"others_max_abs={max(abs(x) for x in small_diff):.3f}",
    ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
