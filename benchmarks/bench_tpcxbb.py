"""TPCx-BB query study: the single-stage Fig. 4 A/B plus the
multi-stage QUERY-MIX pipelines.

Section 1 (Fig. 4) — TPCx-BB (4 nodes): UDF queries under legacy static
round-robin vs DySkew.  Paper claims reproduced: Q10 +43 % and Q19
+36 % (the skewed sentiment-analysis UDF queries); all other queries
within ±5 %.

Section 2 (pipelines) — chained-stage shapes from
`repro.sim.workload.pipeline_suite` (fan-out explode, groupby
attenuate, skew-amplifying collision chain, 4-stage ETL mix) run as a
per-stage policy A/B: every stage's redistribution strategy overridden
to dyskew / static_rr / p2c in turn, same seeds per arm.  Reported per
scenario: end-to-end makespan per arm, dyskew's improvement over
static_rr, and the max stage-over-stage skew amplification the shuffles
produced — the propagation signal the single-stage benches cannot see.
"""

from __future__ import annotations

import argparse
from typing import List, Tuple

from repro.sim.engine import ClusterConfig, Simulator
from repro.sim.replay import (
    dyskew_strategy,
    improvement,
    legacy_strategy,
    run_pipeline_ab,
)
from repro.sim.workload import generate_query, pipeline_suite, tpcxbb_suite

Row = Tuple[str, float, str]

PIPELINE_ARMS = ("dyskew", "static_rr", "p2c")


def _fig4(quick: bool) -> List[Row]:
    cluster = ClusterConfig(num_nodes=4)
    suite = tpcxbb_suite()
    if quick:
        suite = [p for p in suite if p.name in ("q05", "q10", "q19", "q22")]
    rows: List[Row] = []
    big_gain, small_diff = [], []
    for i, prof in enumerate(suite):
        batches = generate_query(prof, cluster.num_workers, seed=100 + i)
        rr = Simulator(cluster, legacy_strategy(prof), seed=i).run_query(batches)
        dk = Simulator(cluster, dyskew_strategy(prof), seed=i).run_query(batches)
        impr = improvement(rr.latency, dk.latency)
        rows.append((
            f"fig4_tpcxbb_{prof.name}",
            dk.latency * 1e6,
            f"improvement={impr:+.3f};legacy_us={rr.latency*1e6:.0f}",
        ))
        (big_gain if prof.name in ("q10", "q19") else small_diff).append(impr)
    rows.append((
        "fig4_summary",
        0.0,
        f"q10_q19_improvements={[f'{x:+.2f}' for x in big_gain]};"
        f"others_max_abs={max(abs(x) for x in small_diff):.3f}",
    ))
    return rows


def _pipelines(quick: bool) -> List[Row]:
    cluster = ClusterConfig(num_nodes=4)
    rows: List[Row] = []
    for name, stages, inputs in pipeline_suite(quick=quick):
        ab = run_pipeline_ab(stages, inputs, cluster,
                             kinds=PIPELINE_ARMS, seed=13)
        dk = ab["dyskew"]
        for arm in PIPELINE_ARMS:
            s = ab[arm]
            amps = [a for a in s["amplification"] if a == a]  # drop NaN
            rows.append((
                f"pipeline_{name}_{arm}",
                s["makespan"] * 1e6,
                f"stages={len(s['stages'])};"
                f"stage_sum_us={s['stage_makespan_sum']*1e6:.0f};"
                f"max_amplification={max(amps) if amps else 1.0:.2f};"
                f"final_work_imb={s['work_imbalance'][-1]:.2f}",
            ))
        impr = improvement(ab["static_rr"]["makespan"], dk["makespan"])
        rows.append((
            f"pipeline_{name}_summary",
            0.0,
            f"dyskew_vs_static_rr={impr:+.3f};"
            f"dyskew_vs_p2c={improvement(ab['p2c']['makespan'], dk['makespan']):+.3f}",
        ))
    return rows


def run(quick: bool = False) -> List[Row]:
    return _fig4(quick) + _pipelines(quick)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer Fig.4 queries, ~4x smaller "
                         "pipeline row counts")
    args = ap.parse_args()
    for r in run(quick=args.quick):
        print(",".join(str(x) for x in r))
