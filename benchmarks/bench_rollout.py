"""Fig. 5 — production rollout: population P99 trend + applied fraction.

Paper claims reproduced: redistribution automatically applied to ≈37.6 % of
Snowpark UDF queries; overall P99 execution-time improvement ≈20.4 %.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.sim.engine import ClusterConfig
from repro.sim.replay import improvement, run_ab
from repro.sim.workload import production_mix

Row = Tuple[str, float, str]


def run(quick: bool = False) -> List[Row]:
    cluster = ClusterConfig(num_nodes=4)
    profiles = production_mix(num_queries=60 if quick else 200)
    suites = run_ab(profiles, cluster, seed=42)
    rr, dk = suites["legacy"], suites["dyskew"]
    applied = dk.applied_fraction()
    p99_impr = improvement(rr.p(99), dk.p(99))
    mean_impr = improvement(rr.mean_latency(), dk.mean_latency())
    return [
        ("fig5_applied_fraction", 0.0, f"applied={applied:.3f} (paper 0.376)"),
        (
            "fig5_p99_improvement",
            dk.p(99) * 1e6,
            f"p99_improvement={p99_impr:+.3f} (paper +0.204)",
        ),
        (
            "fig5_mean_improvement",
            dk.mean_latency() * 1e6,
            f"mean_improvement={mean_impr:+.3f}",
        ),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
