"""Pallas kernel latencies (interpret mode on CPU — correctness-path
timing only; TPU timing happens on hardware) + oracle agreement."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _time(fn, *args, iters=5):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False) -> List[Row]:
    from repro.kernels.dispatch.kernel import dispatch_gather
    from repro.kernels.dispatch.ref import dispatch_gather_ref
    from repro.kernels.histogram.kernel import load_histogram
    from repro.kernels.histogram.ref import load_histogram_ref
    from repro.kernels.ssd_scan.kernel import ssd_state_scan
    from repro.kernels.ssd_scan.ref import ssd_state_scan_ref
    from repro.kernels.topk_gating.kernel import topk_gating
    from repro.kernels.topk_gating.ref import topk_gating_ref

    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    T, S, D = (256, 512, 256) if quick else (1024, 2048, 512)
    x = jax.random.normal(key, (T, D), jnp.bfloat16)
    src = jax.random.randint(key, (S,), 0, T)
    valid = jnp.ones((S,), bool)
    us = _time(lambda: dispatch_gather(x, src, valid, interpret=True))
    ref = dispatch_gather_ref(x, src, valid)
    out = dispatch_gather(x, src, valid, interpret=True)
    err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    rows.append(("kernel_dispatch_gather", us, f"max_err={err:.1e};shape=({T},{S},{D})"))

    N, E = (2048, 64) if quick else (8192, 384)
    ids = jax.random.randint(key, (N,), 0, E)
    us = _time(lambda: load_histogram(ids, num_dest=E, interpret=True))
    err = float(jnp.abs(load_histogram(ids, num_dest=E, interpret=True)
                        - load_histogram_ref(ids, E)).max())
    rows.append(("kernel_histogram", us, f"max_err={err:.1e};N={N};E={E}"))

    Tt, Et, k = (256, 64, 4) if quick else (1024, 384, 8)
    logits = jax.random.normal(key, (Tt, Et))
    us = _time(lambda: topk_gating(logits, k=k, interpret=True))
    w, idx = topk_gating(logits, k=k, interpret=True)
    wr, idxr = topk_gating_ref(logits, k)
    agree = float(jnp.mean((idx == idxr).astype(jnp.float32)))
    rows.append(("kernel_topk_gating", us, f"idx_agree={agree:.4f};T={Tt};E={Et};k={k}"))

    C, H, P, Nn = (8, 8, 32, 32) if quick else (32, 16, 64, 128)
    states = jax.random.normal(key, (C, H, P, Nn))
    decay = jax.nn.sigmoid(jax.random.normal(key, (C, H)))
    us = _time(lambda: ssd_state_scan(states, decay, interpret=True))
    err = float(jnp.abs(ssd_state_scan(states, decay, interpret=True)
                        - ssd_state_scan_ref(states, decay)).max())
    rows.append(("kernel_ssd_state_scan", us, f"max_err={err:.1e};C={C};H={H}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
