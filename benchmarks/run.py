"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` (or env
REPRO_BENCH_QUICK=1) shrinks workloads for CI-speed runs.  Individual
benches can be selected with ``--only <substring>``.
"""

from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
import traceback

# Make `python benchmarks/run.py` work from anywhere: the repo root (for
# the `benchmarks` package) and src/ (for `repro`) must be importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

BENCHES = [
    "benchmarks.bench_cluster_scaling",   # Fig. 3
    "benchmarks.bench_multi_tenant",      # concurrent queries, shared cluster
    "benchmarks.bench_tpcxbb",            # Fig. 4
    "benchmarks.bench_rollout",           # Fig. 5
    "benchmarks.bench_heavy_rows",        # §III.B row-size case study
    "benchmarks.bench_self_skip",         # §III.B forced-remote case study
    "benchmarks.bench_moe_dispatch",      # technique → TPU (MoE adaptive dispatch)
    "benchmarks.bench_kernels",           # Pallas kernel latencies (interpret)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    default=bool(os.environ.get("REPRO_BENCH_QUICK")))
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--check-docs", action="store_true",
                    help="run the README/ARCHITECTURE doc-link check "
                         "instead of the benches (see tools/check_docs.py)")
    args = ap.parse_args()

    if args.check_docs:
        sys.path.insert(0, os.path.join(_ROOT, "tools"))
        import check_docs

        sys.exit(check_docs.main())

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in BENCHES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
        except ModuleNotFoundError:
            print(f"{mod_name},0,SKIP (module not present)")
            continue
        try:
            for name, us, derived in mod.run(quick=args.quick):
                print(f"{name},{us:.1f},{derived}")
            print(f"{mod_name.split('.')[-1]}_wall,"
                  f"{(time.time()-t0)*1e6:.0f},total bench wall time")
        except Exception:
            failures += 1
            print(f"{mod_name},0,FAILED")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
