"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and records the run as
machine-readable JSON (default ``BENCH_3.json`` in the repo root,
``--json`` overrides) so the perf trajectory survives across PRs: per
bench the wall time and every row with its derived key=value pairs
(speedups vs legacy, tenant counts, ...) parsed into a dict.
``--quick`` (or env REPRO_BENCH_QUICK=1) shrinks workloads for CI-speed
runs.  Individual benches can be selected with ``--only <substring>``.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time
import traceback

# Make `python benchmarks/run.py` work from anywhere: the repo root (for
# the `benchmarks` package) and src/ (for `repro`) must be importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

BENCHES = [
    "benchmarks.bench_cluster_scaling",   # Fig. 3
    "benchmarks.bench_multi_tenant",      # concurrent queries, shared cluster
    "benchmarks.bench_tpcxbb",            # Fig. 4
    "benchmarks.bench_rollout",           # Fig. 5
    "benchmarks.bench_heavy_rows",        # §III.B row-size case study
    "benchmarks.bench_self_skip",         # §III.B forced-remote case study
    "benchmarks.bench_moe_dispatch",      # technique → TPU (MoE adaptive dispatch)
    "benchmarks.bench_kernels",           # Pallas kernel latencies (interpret)
]


def _jsonable(obj):
    """Deep-copy with NaN/±inf floats replaced by None (strict JSON)."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, float) and (obj != obj or obj in (float("inf"),
                                                         float("-inf"))):
        return None
    return obj


def _parse_derived(derived: str) -> dict:
    """Best-effort split of a row's derived string into key=value pairs
    (values parsed as float where they look numeric, trailing 'x'/'%'
    units stripped); non-conforming fragments land under 'notes'."""
    out: dict = {}
    notes = []
    for frag in str(derived).split(";"):
        frag = frag.strip()
        if not frag:
            continue
        if "=" not in frag:
            notes.append(frag)
            continue
        k, v = frag.split("=", 1)
        try:
            out[k] = float(v.rstrip("x%"))
        except ValueError:
            out[k] = v
    if notes:
        out["notes"] = "; ".join(notes)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    default=bool(os.environ.get("REPRO_BENCH_QUICK")))
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--json", type=str,
                    default=os.path.join(_ROOT, "BENCH_3.json"),
                    help="where to write the machine-readable record of "
                         "this run ('' disables)")
    ap.add_argument("--check-docs", action="store_true",
                    help="run the README/ARCHITECTURE doc-link check "
                         "instead of the benches (see tools/check_docs.py)")
    args = ap.parse_args()

    if args.check_docs:
        sys.path.insert(0, os.path.join(_ROOT, "tools"))
        import check_docs

        sys.exit(check_docs.main())

    print("name,us_per_call,derived")
    failures = 0
    record = {
        "schema": 1,
        "created_unix": time.time(),
        "quick": bool(args.quick),
        "only": args.only,
        "benches": [],
    }
    for mod_name in BENCHES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
        except ModuleNotFoundError:
            print(f"{mod_name},0,SKIP (module not present)")
            record["benches"].append(
                {"suite": mod_name, "status": "skipped"}
            )
            continue
        entry = {"suite": mod_name, "status": "ok", "rows": []}
        try:
            for name, us, derived in mod.run(quick=args.quick):
                print(f"{name},{us:.1f},{derived}")
                entry["rows"].append({
                    "name": name,
                    "us_per_call": float(us),
                    "derived": _parse_derived(derived),
                })
            entry["wall_s"] = time.time() - t0
            print(f"{mod_name.split('.')[-1]}_wall,"
                  f"{entry['wall_s']*1e6:.0f},total bench wall time")
        except Exception:
            failures += 1
            entry["status"] = "failed"
            entry["wall_s"] = time.time() - t0
            print(f"{mod_name},0,FAILED")
            traceback.print_exc()
        record["benches"].append(entry)
    default_json = ap.get_default("json")
    demoting = bool(args.only)
    if args.quick and not demoting and os.path.isfile(default_json):
        # A quick run may refresh a quick record but must not clobber a
        # full-run record; pass --json explicitly to force.
        try:
            with open(default_json, encoding="utf-8") as f:
                demoting = json.load(f).get("quick") is False
        except (OSError, ValueError):
            pass
    if args.json and demoting and args.json == default_json:
        print(f"# partial/demoting run: not overwriting {default_json} "
              "(pass --json to force)", file=sys.stderr)
    elif args.json:
        record["total_wall_s"] = sum(
            b.get("wall_s", 0.0) for b in record["benches"]
        )
        with open(args.json, "w", encoding="utf-8") as f:
            # NaN is a legal bench value (e.g. Jain's index of a class
            # with zero completions) but not legal JSON — null it.
            json.dump(_jsonable(record), f, indent=2, sort_keys=True,
                      allow_nan=False)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
