"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and records the run as
machine-readable JSON so the perf trajectory survives across PRs: per
bench the wall time and every row with its derived key=value pairs
(speedups vs legacy, tenant counts, ...) parsed into a dict.  The
default output derives the NEXT free ``BENCH_<n>.json`` index in the
repo root from the records already present (so each PR's run lands in a
fresh, diffable file instead of clobbering the previous PR's baseline);
``--out``/``--json`` pin an explicit path.  ``--quick`` (or env
REPRO_BENCH_QUICK=1) shrinks workloads for CI-speed runs.  Individual
benches can be selected with ``--only <substring>``.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import re
import subprocess
import sys
import time
import traceback

# Make `python benchmarks/run.py` work from anywhere: the repo root (for
# the `benchmarks` package) and src/ (for `repro`) must be importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

BENCHES = [
    "benchmarks.bench_cluster_scaling",   # Fig. 3
    "benchmarks.bench_multi_tenant",      # concurrent queries, shared cluster
    "benchmarks.bench_tpcxbb",            # Fig. 4
    "benchmarks.bench_rollout",           # Fig. 5
    "benchmarks.bench_heavy_rows",        # §III.B row-size case study
    "benchmarks.bench_self_skip",         # §III.B forced-remote case study
    "benchmarks.bench_moe_dispatch",      # technique → TPU (MoE adaptive dispatch)
    "benchmarks.bench_kernels",           # Pallas kernel latencies (interpret)
]


def _jsonable(obj):
    """Deep-copy with NaN/±inf floats replaced by None (strict JSON)."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, float) and (obj != obj or obj in (float("inf"),
                                                         float("-inf"))):
        return None
    return obj


def _next_bench_json() -> str:
    """Default record path: the next free ``BENCH_<n>.json`` index.

    Previous PRs' records stay untouched, so the trajectory
    (BENCH_3.json vs BENCH_4.json vs ...) is diffable from the repo
    alone.  Explicit ``--out``/``--json`` always wins — use it when
    iterating locally (repeated default runs each mint a fresh index;
    only commit the record that represents the PR).  Records carry a
    ``quick`` flag so a shrunken-workload run can never masquerade as a
    full-run baseline when diffing.
    """
    indices = [0]
    for name in os.listdir(_ROOT):
        m = re.fullmatch(r"BENCH_(\d+)\.json", name)
        if m:
            indices.append(int(m.group(1)))
    return os.path.join(_ROOT, f"BENCH_{max(indices) + 1}.json")


def _git_sha() -> str:
    """HEAD commit of the repo the record was produced from, or
    "unknown" outside a git checkout — provenance for diffing BENCH
    records across PRs (which code produced which numbers)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_ROOT,
            capture_output=True, text=True, timeout=10,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _parse_derived(derived: str) -> dict:
    """Best-effort split of a row's derived string into key=value pairs
    (values parsed as float where they look numeric, trailing 'x'/'%'
    units stripped); non-conforming fragments land under 'notes'."""
    out: dict = {}
    notes = []
    for frag in str(derived).split(";"):
        frag = frag.strip()
        if not frag:
            continue
        if "=" not in frag:
            notes.append(frag)
            continue
        k, v = frag.split("=", 1)
        try:
            out[k] = float(v.rstrip("x%"))
        except ValueError:
            out[k] = v
    if notes:
        out["notes"] = "; ".join(notes)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    default=bool(os.environ.get("REPRO_BENCH_QUICK")))
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--json", "--out", dest="json", type=str, default=None,
                    help="where to write the machine-readable record of "
                         "this run ('' disables; default: the next free "
                         "BENCH_<n>.json in the repo root)")
    ap.add_argument("--check-docs", action="store_true",
                    help="run the README/ARCHITECTURE doc-link check "
                         "instead of the benches (see tools/check_docs.py)")
    ap.add_argument("--seed", type=int,
                    default=int(os.environ.get("REPRO_BENCH_SEED", "0")),
                    help="base seed recorded in the BENCH_<n>.json header "
                         "and exported as REPRO_BENCH_SEED for bench "
                         "modules that consult it (default 0)")
    args = ap.parse_args()
    os.environ["REPRO_BENCH_SEED"] = str(args.seed)

    if args.check_docs:
        sys.path.insert(0, os.path.join(_ROOT, "tools"))
        import check_docs

        sys.exit(check_docs.main())

    selected = [m for m in BENCHES if not args.only or args.only in m]
    if args.only and not selected:
        # A typo'd --only used to print the CSV header and exit 0,
        # which reads as "ran fine, zero rows" in CI logs.
        print(f"error: --only {args.only!r} matches no bench; "
              f"choose a substring of: "
              f"{', '.join(m.split('.')[-1] for m in BENCHES)}",
              file=sys.stderr)
        sys.exit(2)

    print("name,us_per_call,derived")
    failures = 0
    record = {
        "schema": 1,
        "created_unix": time.time(),
        "quick": bool(args.quick),
        "only": args.only,
        "seed": int(args.seed),
        "git_sha": _git_sha(),
        "benches": [],
    }
    for mod_name in selected:
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
        except ModuleNotFoundError:
            print(f"{mod_name},0,SKIP (module not present)")
            record["benches"].append(
                {"suite": mod_name, "status": "skipped"}
            )
            continue
        entry = {"suite": mod_name, "status": "ok", "rows": []}
        try:
            for name, us, derived in mod.run(quick=args.quick):
                print(f"{name},{us:.1f},{derived}")
                entry["rows"].append({
                    "name": name,
                    "us_per_call": float(us),
                    "derived": _parse_derived(derived),
                })
            entry["wall_s"] = time.time() - t0
            print(f"{mod_name.split('.')[-1]}_wall,"
                  f"{entry['wall_s']*1e6:.0f},total bench wall time")
        except Exception:
            failures += 1
            entry["status"] = "failed"
            entry["wall_s"] = time.time() - t0
            print(f"{mod_name},0,FAILED")
            traceback.print_exc()
        record["benches"].append(entry)
    out_json = args.json
    if out_json is None:
        # A partial (--only) run would pollute the trajectory with an
        # incomplete numbered record; require an explicit path for it.
        out_json = "" if args.only else _next_bench_json()
        if args.only:
            print("# partial run (--only): no BENCH_<n>.json written "
                  "(pass --out to force)", file=sys.stderr)
    if out_json:
        record["total_wall_s"] = sum(
            b.get("wall_s", 0.0) for b in record["benches"]
        )
        with open(out_json, "w", encoding="utf-8") as f:
            # NaN is a legal bench value (e.g. Jain's index of a class
            # with zero completions) but not legal JSON — null it.
            json.dump(_jsonable(record), f, indent=2, sort_keys=True,
                      allow_nan=False)
            f.write("\n")
        print(f"# wrote {out_json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
