"""§III.B case study — 'Redistribution Overhead Scales with Row Size'.

Paper claims reproduced: unguarded eager redistribution of 100 GB+ blobs
regresses up to 20×; the Row Size Model (batch-density + row-size guard)
plus the cost gate recover to parity with local processing.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core.types import DySkewConfig, Policy
from repro.sim.engine import ClusterConfig, Simulator, StrategyConfig
from repro.sim.workload import generate_query, heavy_rows_case

Row = Tuple[str, float, str]


def run(quick: bool = False) -> List[Row]:
    cluster = ClusterConfig(num_nodes=4)
    prof = heavy_rows_case(row_gb=1.0, n_rows=48)
    batches = generate_query(prof, cluster.num_workers, seed=0)

    strategies = {
        "none": StrategyConfig(kind="none"),
        "eager_unguarded": StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(
                policy=Policy.EAGER_SNOWPARK, cost_gate=0.0,
                min_batch_density_frac=0.0,
            ),
            enable_density_guard=False,
            enable_cost_gate=False,
        ),
        "eager_guarded": StrategyConfig(kind="dyskew"),
    }
    res = {
        k: Simulator(cluster, st, seed=0).run_query(batches)
        for k, st in strategies.items()
    }
    reg = res["eager_unguarded"].latency / res["none"].latency
    rec = res["eager_guarded"].latency / res["none"].latency
    rows: List[Row] = [
        (
            f"heavy_rows_{k}",
            r.latency * 1e6,
            f"bytes_moved_gb={r.bytes_moved_remote/1e9:.1f}",
        )
        for k, r in res.items()
    ]
    rows.append((
        "heavy_rows_summary",
        0.0,
        f"unguarded_regression={reg:.1f}x (paper: up to 20x);"
        f"guarded_vs_local={rec:.2f}x",
    ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
