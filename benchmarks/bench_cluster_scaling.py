"""Fig. 3 — customer-query replay across cluster sizes (2/4/8 nodes).

Paper claims reproduced:
  * slight regression at 2 nodes,
  * significant latency reductions at 4 and 8 nodes,
  * ~10 % improvement in P99 tail latency,
  * utilization gains growing with cluster size.
"""

from __future__ import annotations

import os
import time
from typing import List, Tuple

from repro.sim.engine import ClusterConfig
from repro.sim.replay import improvement, run_ab, warm_pool
from repro.sim.workload import customer_replay_suite

Row = Tuple[str, float, str]


def _workers() -> int:
    """Simulations are per-query independent; fan out across cores unless
    REPRO_BENCH_WORKERS pins it (0/1 = serial).  Capped: each worker is a
    full Python+jax process, and past ~8 the spawn cost outweighs the
    parallelism for quick-mode suites."""
    env = os.environ.get("REPRO_BENCH_WORKERS")
    if env is not None:
        return int(env)
    return min(os.cpu_count() or 1, 8)


def run(quick: bool = False) -> List[Row]:
    num_queries = 40 if quick else 150
    workers = _workers()
    warm_pool(workers)  # overlap worker spawn with profile generation
    profiles = customer_replay_suite(num_queries=num_queries)
    rows: List[Row] = []
    for nodes in (2, 4, 8):
        cluster = ClusterConfig(num_nodes=nodes)
        t0 = time.time()
        suites = run_ab(profiles, cluster, seed=nodes, workers=workers)
        rr, dk = suites["legacy"], suites["dyskew"]
        mean_impr = improvement(rr.mean_latency(), dk.mean_latency())
        p99_impr = improvement(rr.p(99), dk.p(99))
        p50_impr = improvement(rr.p(50), dk.p(50))
        util_delta = dk.mean_utilization() - rr.mean_utilization()
        rows.append((
            f"fig3_nodes{nodes}_mean_latency_dyskew",
            dk.mean_latency() * 1e6,
            f"mean_improvement={mean_impr:+.3f}",
        ))
        rows.append((
            f"fig3_nodes{nodes}_p99_latency_dyskew",
            dk.p(99) * 1e6,
            f"p99_improvement={p99_impr:+.3f}",
        ))
        rows.append((
            f"fig3_nodes{nodes}_p50",
            dk.p(50) * 1e6,
            f"p50_improvement={p50_impr:+.3f};util_delta={util_delta:+.3f};"
            f"wall_s={time.time()-t0:.1f}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
