"""DySkew technique → TPU: adaptive MoE dispatch vs the static baseline.

Tokens route to experts with a Zipf-skewed distribution (the MoE analogue
of the paper's skewed rows).  The static baseline (NEVER policy = uniform
per-expert capacity, GShard-style) drops overflow tokens on hot experts
while idle experts waste capacity; DySkew's per-EP-shard state machines
commit to redistribution and re-allocate effective capacity
load-proportionally inside the same buffer budget.

Reported: dropped-token fraction (quality proxy) and capacity utilization
(throughput proxy) over a training-step sequence, plus the step at which
the state machines committed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ArchConfig, MoEConfig
from repro.models.layers.moe import SpmdCtx, moe_apply, moe_specs, moe_state_init
from repro.models.param import tree_materialize

Row = Tuple[str, float, str]


def _mk_cfg(adaptive: bool, E=32, k=8, d=128, ff=64) -> ArchConfig:
    return ArchConfig(
        name="bench", family="moe", num_layers=1, d_model=d, num_heads=4,
        num_kv_heads=2, d_ff=ff, vocab_size=256,
        moe=MoEConfig(num_experts=E, top_k=k, expert_ff=ff,
                      capacity_factor=1.25, adaptive=adaptive),
        dtype="float32",
    )


def _skewed_router_bias(E: int, alpha: float) -> jnp.ndarray:
    """Zipf logit bias: makes low-index experts hot."""
    probs = 1.0 / np.arange(1, E + 1) ** alpha
    probs /= probs.sum()
    return jnp.asarray(np.log(probs) - np.log(probs).mean(), jnp.float32)


def run(quick: bool = False) -> List[Row]:
    E, k = 32, 8
    steps = 10 if quick else 30
    B, S = 4, 256
    ctx = SpmdCtx(num_groups=1, num_ep_shards=8)
    rows: List[Row] = []

    for alpha in (0.0, 0.8, 1.5):
        results = {}
        for mode in ("static", "dyskew"):
            cfg = _mk_cfg(adaptive=(mode == "dyskew"), E=E, k=k)
            p = tree_materialize(moe_specs(cfg), jax.random.PRNGKey(0),
                                 dtype_override=jnp.float32)
            # Inject routing skew via a router bias (simulates hot experts).
            p = dict(p)
            p["router"] = p["router"] + _skewed_router_bias(E, alpha)[None, :] * 0.5
            state = moe_state_init(cfg, ctx)
            dropped, imb, dist = [], [], []

            @jax.jit
            def step(state, x):
                y, st, m = moe_apply(p, x, cfg=cfg, state=state, ctx=ctx)
                return st, m

            for i in range(steps):
                x = jax.random.normal(
                    jax.random.PRNGKey(100 + i), (B, S, cfg.d_model)
                )
                state, m = step(state, x)
                dropped.append(float(m["moe_dropped_frac"]))
                imb.append(float(m["moe_shard_imbalance"]))
                dist.append(float(m["moe_distribute_frac"]))
            results[mode] = dict(
                dropped=float(np.mean(dropped[2:])),
                imbalance=float(np.mean(imb[2:])),
                distribute=float(np.mean(dist)),
            )
        s, dy = results["static"], results["dyskew"]
        improvement = (s["dropped"] - dy["dropped"]) / max(s["dropped"], 1e-9)
        rows.append((
            f"moe_dispatch_alpha{alpha}",
            0.0,
            f"static_dropped={s['dropped']:.4f};dyskew_dropped={dy['dropped']:.4f};"
            f"drop_reduction={improvement:+.2%};imbalance={s['imbalance']:.2f}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
