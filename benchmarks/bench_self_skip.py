"""§III.B case study — 'Forced Remote Distribution'.

Paper claims reproduced: forcing redistribution to skip the local worker
(self-exclusion bias) leaves local CPU idle and adds network traffic,
regressing vs the location-agnostic strategy — worst on small clusters.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core.types import DySkewConfig, Policy
from repro.sim.engine import ClusterConfig, Simulator, StrategyConfig
from repro.sim.replay import improvement, scan_arrival_gap
from repro.sim.workload import generate_query, self_skip_case

Row = Tuple[str, float, str]


def run(quick: bool = False) -> List[Row]:
    prof = self_skip_case()
    rows: List[Row] = []
    sizes = (2, 4) if quick else (2, 4, 8)
    for nodes in sizes:
        cluster = ClusterConfig(num_nodes=nodes)
        batches = generate_query(prof, cluster.num_workers, seed=0)
        gap = scan_arrival_gap(prof, cluster)
        agnostic = Simulator(
            cluster,
            StrategyConfig(kind="dyskew",
                           dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK)),
            0,
        ).run_query(batches, gap)
        forced = Simulator(
            cluster,
            StrategyConfig(
                kind="dyskew",
                dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK,
                                    self_skip=True),
            ),
            0,
        ).run_query(batches, gap)
        reg = improvement(forced.latency, agnostic.latency)
        rows.append((
            f"self_skip_nodes{nodes}",
            agnostic.latency * 1e6,
            f"agnostic_gain_over_forced={reg:+.3f};"
            f"extra_net_gb={(forced.bytes_moved_remote-agnostic.bytes_moved_remote)/1e9:.2f}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
