"""Multi-tenant scenario — N concurrent queries on ONE shared cluster.

The paper's production setting runs many Snowpark queries against the
same virtual warehouse at once; the interesting question is how a noisy
(skewed) neighbour degrades everyone else's latency, and how much of that
DySkew claws back versus the legacy static round-robin.  Two traffic
regimes:

  closed-loop — the `multi_tenant_suite` tenants with staggered arrivals
      over shared interpreter pools and NIC uplinks
      (`MultiQuerySimulator`), per-query p50/p99 for legacy vs DySkew;
  open-loop   — a Poisson query stream over two priority classes (gold,
      weight 8; bulk skewed batch work, weight 1) with the weighted
      fair-share admission layer on, reporting per-class p50/p99/p999
      and Jain's fairness index over per-tenant slowdowns, fair share
      on vs off.
"""

from __future__ import annotations

import os
import sys
import time
from typing import List, Tuple

# Make `python benchmarks/bench_multi_tenant.py` work from anywhere (the
# harness `benchmarks/run.py` does the same fix for the whole suite).
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from repro.core.admission import FairShareConfig
from repro.sim.engine import ClusterConfig
from repro.sim.replay import (
    improvement,
    open_loop_rate,
    run_multi_tenant_ab,
    run_open_loop,
)
from repro.sim.workload import (
    ArrivalProcess,
    multi_tenant_suite,
    priority_class_suite,
)

Row = Tuple[str, float, str]


def _closed_loop(quick: bool) -> List[Row]:
    num_tenants = 4 if quick else 8
    rounds = 2 if quick else 4
    cluster = ClusterConfig(num_nodes=4)
    rows: List[Row] = []
    t0 = time.time()
    lat = {"legacy": [], "dyskew": []}
    redist_frac = []
    for r in range(rounds):
        profiles = multi_tenant_suite(num_tenants, seed=41 + r)
        suites = run_multi_tenant_ab(profiles, cluster, seed=r)
        for name, suite in suites.items():
            lat[name].extend(suite.latencies.tolist())
        redist_frac.append(suites["dyskew"].applied_fraction())
    leg = np.array(lat["legacy"])
    dk = np.array(lat["dyskew"])
    for q in (50, 99):
        lq, dq = float(np.percentile(leg, q)), float(np.percentile(dk, q))
        rows.append((
            f"multi_tenant_{num_tenants}q_p{q}_latency_dyskew",
            dq * 1e6,
            f"p{q}_legacy_us={lq * 1e6:.1f};p{q}_improvement="
            f"{improvement(lq, dq):+.3f}",
        ))
    rows.append((
        f"multi_tenant_{num_tenants}q_mean_latency_dyskew",
        float(dk.mean()) * 1e6,
        f"mean_improvement={improvement(float(leg.mean()), float(dk.mean())):+.3f};"
        f"applied_frac={float(np.mean(redist_frac)):.2f};"
        f"wall_s={time.time() - t0:.1f}",
    ))
    return rows


def _open_loop(quick: bool) -> List[Row]:
    """Poisson open-loop stream, two priority classes, fair share on/off."""
    num_queries = 10 if quick else 24
    cluster = ClusterConfig(num_nodes=2 if quick else 4)
    specs = priority_class_suite()
    proc = ArrivalProcess(
        kind="poisson",
        rate=open_loop_rate([p for p, _ in specs], cluster, load=0.75),
    )
    fs_cfg = FairShareConfig(quantum_rows=128.0, heavy_row_bytes=1e6)
    t0 = time.time()
    base = run_open_loop(specs, cluster, proc, num_queries, seed=0)
    fair = run_open_loop(specs, cluster, proc, num_queries, seed=0,
                         fair_share=fs_cfg)
    rows: List[Row] = []
    for cls, stats in fair["per_class"].items():
        b = base["per_class"][cls]
        for pct in ("p50", "p99", "p999"):
            rows.append((
                f"open_loop_poisson_{cls}_{pct}_latency_fair",
                stats[pct] * 1e6,
                f"{pct}_nofair_us={b[pct] * 1e6:.1f};n={stats['n']};"
                f"mean_slowdown={stats['mean_slowdown']:.2f}",
            ))
    rows.append((
        "open_loop_poisson_jain_fairness_fair",
        fair["jain"],
        f"jain_nofair={base['jain']:.3f};queries={num_queries};"
        f"rate_qps={proc.rate:.2f};wall_s={time.time() - t0:.1f}",
    ))
    return rows


def run(quick: bool = False) -> List[Row]:
    return _closed_loop(quick) + _open_loop(quick)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    default=bool(os.environ.get("REPRO_BENCH_QUICK")))
    args = ap.parse_args()
    for r in run(quick=args.quick):
        print(",".join(str(x) for x in r))
