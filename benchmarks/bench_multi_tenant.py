"""Multi-tenant scenario — N concurrent queries on ONE shared cluster.

The paper's production setting runs many Snowpark queries against the
same virtual warehouse at once; the interesting question is how a noisy
(skewed) neighbour degrades everyone else's latency, and how much of that
DySkew claws back versus the legacy static round-robin.  This bench
interleaves the `multi_tenant_suite` tenants with staggered arrivals over
shared interpreter pools and NIC uplinks (`MultiQuerySimulator`) and
reports per-query p50/p99 latency for legacy vs DySkew.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.sim.engine import ClusterConfig
from repro.sim.replay import improvement, run_multi_tenant_ab
from repro.sim.workload import multi_tenant_suite

Row = Tuple[str, float, str]


def run(quick: bool = False) -> List[Row]:
    num_tenants = 4 if quick else 8
    rounds = 2 if quick else 4
    cluster = ClusterConfig(num_nodes=4)
    rows: List[Row] = []
    t0 = time.time()
    lat = {"legacy": [], "dyskew": []}
    redist_frac = []
    for r in range(rounds):
        profiles = multi_tenant_suite(num_tenants, seed=41 + r)
        suites = run_multi_tenant_ab(profiles, cluster, seed=r)
        for name, suite in suites.items():
            lat[name].extend(suite.latencies.tolist())
        redist_frac.append(suites["dyskew"].applied_fraction())
    leg = np.array(lat["legacy"])
    dk = np.array(lat["dyskew"])
    for q in (50, 99):
        lq, dq = float(np.percentile(leg, q)), float(np.percentile(dk, q))
        rows.append((
            f"multi_tenant_{num_tenants}q_p{q}_latency_dyskew",
            dq * 1e6,
            f"p{q}_legacy_us={lq * 1e6:.1f};p{q}_improvement="
            f"{improvement(lq, dq):+.3f}",
        ))
    rows.append((
        f"multi_tenant_{num_tenants}q_mean_latency_dyskew",
        float(dk.mean()) * 1e6,
        f"mean_improvement={improvement(float(leg.mean()), float(dk.mean())):+.3f};"
        f"applied_frac={float(np.mean(redist_frac)):.2f};"
        f"wall_s={time.time() - t0:.1f}",
    ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
