"""Multi-tenant scenario — N concurrent queries on ONE shared cluster.

The paper's production setting runs many Snowpark queries against the
same virtual warehouse at once; the interesting question is how a noisy
(skewed) neighbour degrades everyone else's latency, and how much of that
DySkew claws back versus the legacy static round-robin.  Three traffic
regimes:

  closed-loop — the `multi_tenant_suite` tenants with staggered arrivals
      over shared interpreter pools and NIC uplinks
      (`MultiQuerySimulator`), per-query p50/p99 for legacy vs DySkew;
  open-loop   — a Poisson query stream over two priority classes (gold,
      weight 8; bulk skewed batch work, weight 1) with the weighted
      fair-share admission layer on, reporting per-class p50/p99/p999
      and Jain's fairness index over per-tenant slowdowns, fair share
      on vs off;
  many        — the hundreds-of-tenants scaling study (``--many``;
      128–512 open-loop tenants from `many_tenants_suite`): the SAME
      tenants run once with per-tenant state-machine ticks
      (``batch_ticks=False``) and once with the batched
      `BatchedLinkSim` path (``batch_ticks=True``, one jitted tick call
      per cadence), reporting the tick-batching wall-clock speedup;
      plus the closed-form 'none' fast path vs the event loop on
      disjoint-producer tenants;
  slo         — the SLO-layer study (``--slo``; `slo_suite` — gold/
      silver classes with deadlines + deadline-free bulk — under
      open-loop OVERLOAD): four arms on identical traffic — weight-only
      fair share, deadline-aware admission (EDF credit boost),
      deadline-aware + preemption, and deadline-aware + warehouse
      autoscaling — reporting per-class SLO attainment (fraction of
      queries meeting their deadline) and p99 tardiness.
  tournament  — the policy tournament (``--tournament``): every policy
      registered in `repro.core.policy` (built-ins plus plugins) runs
      the SAME skewed/overload/SLO open-loop traffic and emits one
      report-card row per policy — p99 latency, Jain fairness, SLO
      attainment, bytes moved, decision overhead — plus a same-seed
      reproducibility check for the stochastic entrants.
  faults      — the fault-injection economics study (``--faults``;
      `faults_suite` tenants crossed with seeded `hazard_schedule`
      failure rates): static round-robin vs deadline-aware DySkew vs
      deadline-aware + autoscale, each at every failure rate, reporting
      SLO attainment, worker-seconds spent (wasted + re-executed
      service billed honestly) and the resulting cost-per-SLO frontier.
"""

from __future__ import annotations

import gc
import os
import sys
import time
from typing import List, Tuple

# Make `python benchmarks/bench_multi_tenant.py` work from anywhere (the
# harness `benchmarks/run.py` does the same fix for the whole suite).
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from repro.core.admission import (
    AutoscaleConfig,
    DeadlineConfig,
    FairShareConfig,
)
from repro.core.policy import available_policies
from repro.core.types import DySkewConfig, Policy, SkewModelKind
from repro.sim.engine import (
    ClusterConfig,
    MultiQuerySimulator,
    StrategyConfig,
    TenantQuery,
)
from repro.sim.faults import hazard_schedule
from repro.sim.replay import (
    improvement,
    legacy_strategy,
    open_loop_rate,
    open_loop_tenants,
    run_multi_tenant_ab,
    run_open_loop,
)
from repro.sim.workload import (
    ArrivalProcess,
    QueryProfile,
    faults_suite,
    generate_query,
    many_tenants_suite,
    multi_tenant_suite,
    priority_class_suite,
    slo_suite,
)

Row = Tuple[str, float, str]


def _closed_loop(quick: bool) -> List[Row]:
    num_tenants = 4 if quick else 8
    rounds = 2 if quick else 4
    cluster = ClusterConfig(num_nodes=4)
    rows: List[Row] = []
    t0 = time.time()
    lat = {"legacy": [], "dyskew": []}
    redist_frac = []
    for r in range(rounds):
        profiles = multi_tenant_suite(num_tenants, seed=41 + r)
        suites = run_multi_tenant_ab(profiles, cluster, seed=r)
        for name, suite in suites.items():
            lat[name].extend(suite.latencies.tolist())
        redist_frac.append(suites["dyskew"].applied_fraction())
    leg = np.array(lat["legacy"])
    dk = np.array(lat["dyskew"])
    for q in (50, 99):
        lq, dq = float(np.percentile(leg, q)), float(np.percentile(dk, q))
        rows.append((
            f"multi_tenant_{num_tenants}q_p{q}_latency_dyskew",
            dq * 1e6,
            f"p{q}_legacy_us={lq * 1e6:.1f};p{q}_improvement="
            f"{improvement(lq, dq):+.3f}",
        ))
    rows.append((
        f"multi_tenant_{num_tenants}q_mean_latency_dyskew",
        float(dk.mean()) * 1e6,
        f"mean_improvement={improvement(float(leg.mean()), float(dk.mean())):+.3f};"
        f"applied_frac={float(np.mean(redist_frac)):.2f};"
        f"wall_s={time.time() - t0:.1f}",
    ))
    return rows


def _open_loop(quick: bool) -> List[Row]:
    """Poisson open-loop stream, two priority classes, fair share on/off."""
    num_queries = 10 if quick else 24
    cluster = ClusterConfig(num_nodes=2 if quick else 4)
    specs = priority_class_suite()
    proc = ArrivalProcess(
        kind="poisson",
        rate=open_loop_rate([p for p, _ in specs], cluster, load=0.75),
    )
    fs_cfg = FairShareConfig(quantum_rows=128.0, heavy_row_bytes=1e6)
    t0 = time.time()
    base = run_open_loop(specs, cluster, proc, num_queries, seed=0)
    fair = run_open_loop(specs, cluster, proc, num_queries, seed=0,
                         fair_share=fs_cfg)
    rows: List[Row] = []
    for cls, stats in fair["per_class"].items():
        b = base["per_class"][cls]
        for pct in ("p50", "p99", "p999"):
            rows.append((
                f"open_loop_poisson_{cls}_{pct}_latency_fair",
                stats[pct] * 1e6,
                f"{pct}_nofair_us={b[pct] * 1e6:.1f};n={stats['n']};"
                f"mean_slowdown={stats['mean_slowdown']:.2f}",
            ))
    rows.append((
        "open_loop_poisson_jain_fairness_fair",
        fair["jain"],
        f"jain_nofair={base['jain']:.3f};queries={num_queries};"
        f"rate_qps={proc.rate:.2f};wall_s={time.time() - t0:.1f}",
    ))
    return rows


def _many_strategy() -> StrategyConfig:
    """One homogeneous dyskew strategy for the scaling study: identical
    (config, cadence) across tenants puts the whole fleet in ONE batched
    tick group — the regime ROADMAP's 'hundreds of tenants' rung names.
    Distribute-Late is the production-default policy (Fig. 5: ~55 % of
    the population): every link keeps ticking its skew model, but only
    genuinely skewed tenants redistribute, so the study isolates tick
    overhead rather than routing volume.  The 8 ms metrics cadence is
    the fine-grained end of the engine's range — small queries need a
    responsive skew signal — and is exactly where per-tenant tick
    dispatch drowns the event loop at N≳64."""
    return StrategyConfig(
        kind="dyskew",
        dyskew=DySkewConfig(
            policy=Policy.LATE,
            skew_model=SkewModelKind.IDLE_TIME,
            n_strikes=2,
        ),
        tick_interval=8e-3,
    )


def _many_tenants(quick: bool) -> List[Row]:
    """Tick-batching A/B at 128–512 tenants: same tenants, same cluster,
    per-tenant jit ticks vs ONE BatchedLinkSim call per cadence.

    Arrivals are snapped onto the shared tick grid (``grid_align`` —
    the metrics subsystem quantizes observation to tick boundaries
    anyway), which puts the whole homogeneous fleet inside the PROVEN
    batched-tick equivalence envelope: the batched arm here is the AUTO
    default (``batch_ticks=None``), runs bit-identically to the
    per-tenant arm, and the reported speedup compares two identical
    trajectories.  A third arm disables the closed-form drain to isolate
    how much of the win comes from exiting the heap once arrivals are
    exhausted; the per-kind event counters quantify the drain/coalescing
    event reduction directly."""
    counts = [128] if quick else [128, 256, 512]
    cluster = ClusterConfig(num_nodes=2)
    specs = many_tenants_suite(counts[-1], seed=71)
    st = _many_strategy()
    rows: List[Row] = []
    for num in counts:
        # Sustained overload (the warehouse is offered 3x its service
        # capacity): queues build, tenants stay live for many ticks, and
        # the per-tenant tick dispatch becomes the dominant loop cost —
        # exactly the regime the batched path exists for.
        proc = ArrivalProcess(
            kind="poisson",
            rate=open_loop_rate([p for p, _ in specs], cluster, load=3.0),
        )
        tenants = open_loop_tenants(
            specs, cluster, lambda prof: st, proc, num, seed=1,
            grid_align=st.tick_interval,
        )

        def timed(repeats: int, **sim_kw):
            # timeit-style min-of-repeats: the box is a shared container
            # and a noise spike landing inside one measurement window
            # would otherwise dominate the ratio.  Both arms get the
            # SAME repeat count so the min does not bias the speedup.
            best_wall, res, counts_ev = float("inf"), None, {}
            gc.collect()  # don't let earlier suites' garbage land here
            for _ in range(repeats):
                sim = MultiQuerySimulator(cluster, **sim_kw)
                t0 = time.time()
                r = sim.run(tenants)
                best_wall = min(best_wall, time.time() - t0)
                res, counts_ev = r, sim.last_event_counts
            return res, best_wall, counts_ev

        repeats = 2 if num <= 128 else 1
        res_per, wall_per, _ = timed(repeats, batch_ticks=False)
        # AUTO arm: grid-aligned arrivals batch by default.
        res_bat, wall_bat, ev = timed(repeats, batch_ticks=None)
        _, wall_nodrain, ev_nd = timed(
            repeats, batch_ticks=None, closed_form_drain=False
        )
        mean_per = float(np.mean([r.latency for r in res_per]))
        mean_bat = float(np.mean([r.latency for r in res_bat]))
        ticks_per = sum(r.num_ticks for r in res_per)
        rows.append((
            f"many_tenants_{num}q_batched_tick_wall",
            wall_bat * 1e6,
            f"per_tenant_wall_us={wall_per * 1e6:.0f};"
            f"speedup={wall_per / max(wall_bat, 1e-9):.2f}x;tenants={num};"
            f"ticks_per_tenant_mode={ticks_per};"
            f"mean_lat_batched_s={mean_bat:.3f};"
            f"mean_lat_per_tenant_s={mean_per:.3f};"
            f"trajectories_identical={int(mean_per == mean_bat)}",
        ))
        heap_ev = ev.get("heap_events", 0)
        heap_ev_nd = ev_nd.get("heap_events", 0)
        rows.append((
            f"many_tenants_{num}q_event_counts",
            heap_ev,
            f"nodrain_wall_us={wall_nodrain * 1e6:.0f};"
            f"drain_speedup={wall_nodrain / max(wall_bat, 1e-9):.2f}x;"
            f"heap_events_nodrain={heap_ev_nd};"
            f"event_reduction={1.0 - heap_ev / max(heap_ev_nd, 1):.3f};"
            f"gticks={ev.get('gtick', 0)};"
            f"drained_heap_events={ev.get('drained_heap_events', 0)};"
            f"drained_chunks={ev.get('drained_chunks', 0)};"
            f"drained_ticks={ev.get('drained_ticks', 0)};"
            f"arrivals_in_runs={ev.get('arrivals_in_runs', 0)};"
            f"enqueues_coalesced={ev.get('enqueues_coalesced', 0)};"
            f"batched_waterfill_rows={ev.get('waterfill_batched_rows', 0)}",
        ))
    # Closed-form 'none' fast path: disjoint-producer tenants (one per
    # worker), event loop vs the prefix-sum closed form.
    n = cluster.num_workers
    prof = QueryProfile(
        name="many_none", n_rows=40_000 if not quick else 16_000,
        mean_row_cost=1e-3, cost_sigma=0.8, batch_rows=1 << 30,
    )
    full = generate_query(prof, n, seed=5)
    none_tenants = [
        TenantQuery(
            name=f"none_{p:02d}",
            streams=[s if i == p else [] for i, s in enumerate(full)],
            strategy=StrategyConfig(kind="none"),
            arrival=0.01 * p,
        )
        for p in range(n)
    ]
    wall_loop = wall_cf = float("inf")
    res_loop = res_cf = None
    gc.collect()
    for _ in range(3):  # min-of-3: these timings are milliseconds
        t0 = time.time()
        res_loop = MultiQuerySimulator(
            cluster, none_closed_form=False).run(none_tenants)
        wall_loop = min(wall_loop, time.time() - t0)
        t0 = time.time()
        res_cf = MultiQuerySimulator(
            cluster, none_closed_form=True).run(none_tenants)
        wall_cf = min(wall_cf, time.time() - t0)
    err = max(
        abs(a.latency - b.latency) / a.latency
        for a, b in zip(res_loop, res_cf)
    )
    rows.append((
        "many_none_closed_form_wall",
        wall_cf * 1e6,
        f"event_loop_wall_us={wall_loop * 1e6:.0f};"
        f"speedup={wall_loop / max(wall_cf, 1e-9):.1f}x;"
        f"tenants={n};max_rel_latency_err={err:.2e}",
    ))
    return rows


def _slo(quick: bool) -> List[Row]:
    """SLO layer under open-loop OVERLOAD (the warehouse is offered
    ~2.5x its service capacity, so queueing is unavoidable and admission
    ORDER is what decides who meets a deadline): identical traffic from
    `slo_suite` (gold 0.5s / silver 2.0s deadlines + deadline-free bulk)
    through four arms — weight-only fair share, deadline-aware admission
    (EDF credit boost), + preemption of admitted-but-unstarted rows, and
    + warehouse autoscaling (which may also GROW the pool instead of
    only reordering entry).  Reported: per-class SLO attainment and p99
    tardiness, overall attainment, preempted rows, applied resizes."""
    num_queries = 14 if quick else 32
    cluster = ClusterConfig(num_nodes=2 if quick else 4)
    specs = slo_suite()
    proc = ArrivalProcess(
        kind="poisson",
        rate=open_loop_rate([p for p, _, _ in specs], cluster, load=2.5),
    )
    fs = FairShareConfig(quantum_rows=128.0, heavy_row_bytes=1e6)
    dc = DeadlineConfig(urgency_horizon=1.0, boost_quanta=4.0)
    # Autoscale arm: start at half the warehouse, allowed to grow to all
    # of it under backlog/attainment pressure.
    asc = AutoscaleConfig(
        min_workers=cluster.num_workers // 2,
        max_workers=cluster.num_workers,
        backlog_high=48.0, backlog_low=4.0,
        step=cluster.interpreters_per_node,
        interval=0.1, cooldown=0.2,
    )
    t0 = time.time()
    arms = [
        ("fairshare", dict()),
        ("deadline", dict(deadline_aware=True, deadline_cfg=dc)),
        ("preempt", dict(deadline_aware=True, deadline_cfg=dc,
                         preemption=True)),
        ("autoscale", dict(deadline_aware=True, deadline_cfg=dc,
                           preemption=True, autoscale=asc)),
    ]
    outs = {
        name: run_open_loop(specs, cluster, proc, num_queries, seed=0,
                            fair_share=fs, **kw)
        for name, kw in arms
    }
    rows: List[Row] = []
    base = outs["fairshare"]
    for name, _ in arms:
        out = outs[name]
        ev = out["event_counts"]
        for cls in ("gold", "silver"):
            stats = out["per_class"].get(cls)
            if stats is None:
                continue
            rows.append((
                f"slo_{name}_{cls}_attainment",
                stats["slo_attainment"],
                f"p99_tardiness_s={stats['p99_tardiness']:.3f};"
                f"p99_latency_s={stats['p99']:.3f};n={stats['n']}",
            ))
        rows.append((
            f"slo_{name}_overall_attainment",
            out["slo_attainment"],
            f"vs_fairshare={out['slo_attainment'] - base['slo_attainment']:+.3f};"
            f"preempted_rows={ev.get('preempted_rows', 0)};"
            f"resizes_applied={ev.get('resizes_applied', 0)};"
            f"bulk_p99_s={out['per_class']['bulk']['p99']:.2f};"
            f"queries={num_queries};load=2.5",
        ))
    rows.append((
        "slo_section_wall",
        (time.time() - t0) * 1e6,
        f"arms={len(arms)};wall_s={time.time() - t0:.1f}",
    ))
    return rows


def _tournament(quick: bool) -> List[Row]:
    """Policy tournament: one report card per REGISTERED policy.

    Every name in the `repro.core.policy` registry — the ported built-in
    trio plus every plugin — runs the identical open-loop traffic: the
    `slo_suite` classes (gold 0.5s / silver 2.0s deadlines +
    deadline-free skewed bulk) offered at ~2x service capacity with the
    weighted fair-share admission layer on, so skew, overload and SLO
    pressure all bear on the same run.  Per policy: p99/p50 latency,
    Jain's fairness over per-tenant slowdowns, overall SLO attainment,
    remote bytes moved and total decision overhead — the trade-off
    surface a new policy has to earn its place on.  A final row reruns
    the stochastic `p2c` entrant with the same seed and reports
    bit-identity (the injected-RNG reproducibility contract)."""
    num_queries = 10 if quick else 24
    cluster = ClusterConfig(num_nodes=2 if quick else 4)
    specs = slo_suite()
    proc = ArrivalProcess(
        kind="poisson",
        rate=open_loop_rate([p for p, _, _ in specs], cluster, load=2.0),
    )
    fs = FairShareConfig(quantum_rows=128.0, heavy_row_bytes=1e6)
    rows: List[Row] = []

    def arm(pname: str, sim_seed: int):
        t0 = time.time()
        out = run_open_loop(
            specs, cluster, proc, num_queries, seed=0,
            resolve=lambda prof, _k=pname: StrategyConfig(kind=_k),
            fair_share=fs, sim_seed=sim_seed,
        )
        return out, time.time() - t0

    p2c_lats = {}
    for pname in available_policies():
        out, wall = arm(pname, sim_seed=11)
        lats = np.array([r.latency for r in out["results"]])
        if pname == "p2c":
            p2c_lats[11] = lats
        gold = out["per_class"].get("gold", {})
        rows.append((
            f"tournament_{pname}_p99_latency",
            float(np.percentile(lats, 99)) * 1e6,
            f"p50_us={float(np.percentile(lats, 50)) * 1e6:.1f};"
            f"jain={out['jain']:.3f};"
            f"slo_attainment={out.get('slo_attainment', float('nan')):.3f};"
            f"gold_attainment={gold.get('slo_attainment', float('nan')):.3f};"
            f"bytes_moved_gb="
            f"{sum(r.bytes_moved_remote for r in out['results']) / 1e9:.4f};"
            f"decision_overhead_s="
            f"{sum(r.decision_overhead for r in out['results']):.4f};"
            f"rows_redistributed="
            f"{sum(r.rows_redistributed for r in out['results'])};"
            f"queries={num_queries};load=2.0;wall_s={wall:.1f}",
        ))
    # Reproducibility check: the stochastic policy rerun with the SAME
    # injected seed must replay bit-identically; a different seed is
    # allowed (and expected) to diverge.
    out_same, _ = arm("p2c", sim_seed=11)
    out_diff, _ = arm("p2c", sim_seed=12)
    same = bool(np.array_equal(
        p2c_lats[11], np.array([r.latency for r in out_same["results"]])
    ))
    diff_lats = np.array([r.latency for r in out_diff["results"]])
    rows.append((
        "tournament_p2c_same_seed_identical",
        float(same),
        f"cross_seed_differs={int(not np.array_equal(p2c_lats[11], diff_lats))};"
        f"policies={len(available_policies())}",
    ))
    return rows


def _faults(quick: bool) -> List[Row]:
    """Cost-per-SLO frontier under deterministic fault injection
    (``--faults``): the `faults_suite` gold/silver/bulk tenants under
    open-loop overload, crossed with seeded `hazard_schedule` failure
    rates (crashes + spot preemptions + transient slowdowns) and three
    arms — static round-robin under plain fair share, DySkew +
    deadline-aware admission, and DySkew + deadline-aware + warehouse
    autoscaling.  Each cell reports SLO attainment, worker-seconds SPENT
    (busy service + wasted partial service on crashed workers — honest
    spend, re-execution included) and their ratio `cost_per_slo`; the
    closing row checks the frontier claim that a deadline-aware arm
    dominates static round-robin (>= attainment at <= cost) at every
    nonzero failure rate."""
    num_queries = 10 if quick else 22
    cluster = ClusterConfig(num_nodes=2 if quick else 4)
    specs = faults_suite()
    proc = ArrivalProcess(
        kind="poisson",
        rate=open_loop_rate([p for p, _, _ in specs], cluster, load=2.5),
    )
    fs = FairShareConfig(quantum_rows=128.0, heavy_row_bytes=1e6)
    dc = DeadlineConfig(urgency_horizon=1.0, boost_quanta=4.0)
    asc = AutoscaleConfig(
        min_workers=cluster.num_workers // 2,
        max_workers=cluster.num_workers,
        backlog_high=48.0, backlog_low=4.0,
        step=cluster.interpreters_per_node,
        interval=0.1, cooldown=0.2,
    )
    # The hazard horizon must cover the whole run: arrivals span
    # ~num_queries/rate and overload stretches the tail well past the
    # last arrival, so give the hazard process 3x the arrival span.
    # mttr=1.2 keeps crashed workers down long enough that the capacity
    # loss actually shows up in admission order (short outages wash out).
    horizon = 3.0 * num_queries / proc.rate
    rates = [0.0, 1.5] if quick else [0.0, 1.5, 3.0]
    arms = [
        ("static_rr", dict(resolve=legacy_strategy)),
        ("deadline", dict(deadline_aware=True, deadline_cfg=dc)),
        ("deadline_autoscale", dict(deadline_aware=True, deadline_cfg=dc,
                                    autoscale=asc)),
    ]
    rows: List[Row] = []
    t0 = time.time()
    frontier = {}
    for rate in rates:
        faults = None
        if rate > 0.0:
            faults = hazard_schedule(
                seed=17, num_workers=cluster.num_workers,
                num_nodes=cluster.num_nodes, horizon=horizon,
                crash_rate=rate, preempt_rate=rate,
                slowdown_rate=0.5 * rate, mttr=1.2,
                min_live=max(2, cluster.num_workers // 4),
            )
        for name, kw in arms:
            out = run_open_loop(
                specs, cluster, proc, num_queries, seed=0,
                fair_share=fs, faults=faults, **kw,
            )
            fstats = out["fault_stats"]
            frontier[(name, rate)] = (
                out["slo_attainment"], out["cost_per_slo"]
            )
            rec = fstats.get("recovered_rows") or []
            rows.append((
                f"faults_{name}_rate{rate:g}_cost_per_slo",
                out["cost_per_slo"],
                f"slo_attainment={out['slo_attainment']:.3f};"
                f"worker_seconds_spent={out['worker_seconds_spent']:.3f};"
                f"slo_met={out['slo_met_count']};"
                f"injected={len(faults.events) if faults else 0};"
                f"detections={fstats.get('detections', 0)};"
                f"recovered_rows={int(sum(rec))};"
                f"reexecuted_rows={int(sum(fstats.get('reexecuted_rows') or []))};"
                f"wasted_service_s={fstats.get('wasted_service_s', 0.0):.3f};"
                f"transfer_retries={fstats.get('transfer_retries', 0)};"
                f"queries={num_queries};load=2.5",
            ))
    # Frontier claim: at every nonzero rate some deadline-aware arm
    # weakly dominates static round-robin on (attainment up, cost down).
    dominates = all(
        any(
            frontier[(a, r)][0] >= frontier[("static_rr", r)][0]
            and frontier[(a, r)][1] <= frontier[("static_rr", r)][1]
            and frontier[(a, r)] != frontier[("static_rr", r)]
            for a in ("deadline", "deadline_autoscale")
        )
        for r in rates if r > 0.0
    )
    rows.append((
        "faults_frontier_deadline_dominates_static",
        float(dominates),
        f"rates={'|'.join(f'{r:g}' for r in rates)};"
        f"arms={len(arms)};wall_s={time.time() - t0:.1f}",
    ))
    return rows


def run(quick: bool = False) -> List[Row]:
    return (
        _closed_loop(quick) + _open_loop(quick) + _many_tenants(quick)
        + _slo(quick) + _tournament(quick) + _faults(quick)
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    default=bool(os.environ.get("REPRO_BENCH_QUICK")))
    ap.add_argument("--many", action="store_true",
                    help="run ONLY the hundreds-of-tenants tick-batching "
                         "scaling section")
    ap.add_argument("--slo", action="store_true",
                    help="run ONLY the SLO deadline/preemption/autoscale "
                         "section")
    ap.add_argument("--tournament", action="store_true",
                    help="run ONLY the registered-policy tournament "
                         "(one report card per policy)")
    ap.add_argument("--faults", action="store_true",
                    help="run ONLY the fault-injection cost-per-SLO "
                         "frontier (policies x failure rates x "
                         "autoscale)")
    args = ap.parse_args()
    if args.many:
        rows = _many_tenants(args.quick)
    elif args.slo:
        rows = _slo(args.quick)
    elif args.tournament:
        rows = _tournament(args.quick)
    elif args.faults:
        rows = _faults(args.quick)
    else:
        rows = run(quick=args.quick)
    for r in rows:
        print(",".join(str(x) for x in r))
